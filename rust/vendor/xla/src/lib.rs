//! Offline stub of the `xla` PJRT bindings (see DESIGN.md
//! substitutions).
//!
//! The real dependency wraps a PJRT CPU client and is only reachable
//! when the XLA shared libraries are installed. This build environment
//! has neither crates.io nor those libraries, so this stub keeps the
//! [`crate::PjRtClient`] surface type-compatible while failing at
//! *runtime* with a clear message: `PjRtClient::cpu()` errors, the
//! engine never constructs, and every caller already handles that path
//! (the scheduler falls back to the native/packed backends and the
//! PJRT tests skip when no artifacts are present).

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str =
    "xla/PJRT unavailable: this is the offline stub runtime (see DESIGN.md substitutions); \
     use backend native|packed|simulate";

/// Stub error type (implements `std::error::Error` so `?` converts it
/// into the workspace error type).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Host-side literal (shape + i32 payload is all the workspace emits).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<i32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[i32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Element types the workspace reads back.
pub trait NativeType: Sized {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Stub PJRT client: construction always fails (no XLA runtime here).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_guidance() {
        let e = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(e.to_string().contains("offline stub"));
    }

    #[test]
    fn literal_shape_bookkeeping_works() {
        let l = Literal::vec1(&[1, 2, 3, 4]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
