//! The paper's §IV-A verification plan, executed verbatim against the
//! cycle-accurate simulator:
//!
//! * exhaustive multiplicand–multiplier pairs for widths up to 8 bits;
//! * 100 random operand pairs per width for 8–16 bits;
//! * random vector dot products, widths 1–16, lengths 1–1000;
//! * multiple SA topologies, matmuls with varying matrix sizes (up to
//!   the SA dimensions) and vector lengths, outputs checked against
//!   the expected results.

use bitsmm::bits::twos::{max_value, min_value};
use bitsmm::prng::Pcg32;
use bitsmm::sim::array::{SaConfig, SystolicArray};
use bitsmm::sim::driver::{mac_dot, ref_matmul_i64};
use bitsmm::sim::mac_common::MacVariant;
use bitsmm::sim::DEFAULT_ACC_BITS;

/// Exhaustive pairs at widths 1..=8 for both MAC variants.
/// (Paper: "we exhaustively tested all multiplicand–multiplier pairs
/// for bit widths up to 8 bits".) The 8-bit sweep is 65 536 pairs per
/// variant at 16 cycles each — fast enough in release, so no sampling.
#[test]
fn exhaustive_mac_pairs_to_8_bits() {
    for bits in 1..=8u32 {
        let (lo, hi) = (min_value(bits), max_value(bits));
        for a in lo..=hi {
            for b in lo..=hi {
                let expect = (a as i64) * (b as i64);
                for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
                    let (acc, cycles) = mac_dot(variant, &[a], &[b], bits, DEFAULT_ACC_BITS);
                    assert_eq!(acc, expect, "{variant:?} {a}x{b} @{bits}b");
                    assert_eq!(cycles, 2 * bits as u64);
                }
            }
        }
    }
}

/// 100 random pairs per width for widths 8..=16 (paper's random axis).
#[test]
fn random_mac_pairs_8_to_16_bits() {
    let mut rng = Pcg32::new(0x1eaf);
    for bits in 8..=16u32 {
        let (lo, hi) = (min_value(bits), max_value(bits));
        for _ in 0..100 {
            let a = rng.range_i32(lo, hi);
            let b = rng.range_i32(lo, hi);
            for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
                let (acc, _) = mac_dot(variant, &[a], &[b], bits, DEFAULT_ACC_BITS);
                assert_eq!(acc, (a as i64) * (b as i64), "{variant:?} {a}x{b} @{bits}b");
            }
        }
    }
}

/// Random dot products: widths 1–16, vector lengths 1–1000.
#[test]
fn random_dot_products_lengths_1_to_1000() {
    let mut rng = Pcg32::new(0xd07b);
    let lengths = [1usize, 2, 5, 13, 64, 250, 611, 1000];
    for &len in &lengths {
        for _ in 0..2 {
            let bits = 1 + rng.below(16);
            let (lo, hi) = (min_value(bits), max_value(bits));
            let mc: Vec<i32> = (0..len).map(|_| rng.range_i32(lo, hi)).collect();
            let ml: Vec<i32> = (0..len).map(|_| rng.range_i32(lo, hi)).collect();
            let expect: i64 = mc.iter().zip(&ml).map(|(&a, &b)| a as i64 * b as i64).sum();
            for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
                let (acc, cycles) = mac_dot(variant, &mc, &ml, bits, DEFAULT_ACC_BITS);
                assert_eq!(acc, expect, "{variant:?} len={len} bits={bits}");
                assert_eq!(cycles, (len as u64 + 1) * bits as u64, "eq. 8");
            }
        }
    }
}

/// Multiple SA topologies × matrix sizes (up to the SA dims) × vector
/// lengths, both variants — the paper's SA test matrix.
#[test]
fn sa_topologies_and_matrix_sizes() {
    let mut rng = Pcg32::new(0x5a5a);
    let topologies = [(2usize, 2usize), (4, 16), (8, 8), (3, 5)];
    for &(rows, cols) in &topologies {
        for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
            let sa = SaConfig::new(rows, cols, variant);
            let mut arr = SystolicArray::new(sa);
            for &(m, n) in &[(1usize, 1usize), (rows, cols), (rows.min(2), cols.min(3))] {
                for &k in &[1usize, 7, 33] {
                    let bits = 1 + rng.below(8);
                    let (lo, hi) = (min_value(bits), max_value(bits));
                    let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
                    let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
                    let out = arr.matmul(&a, &b, m, k, n, bits).expect("sim matmul");
                    assert_eq!(
                        out.result,
                        ref_matmul_i64(&a, &b, m, k, n),
                        "{variant:?} {rows}x{cols} SA, {m}x{k}x{n} @{bits}b"
                    );
                    // eq. 8 + fill + readout bounds
                    let eq8 = (k as u64 + 1) * bits as u64;
                    assert!(out.stats.compute_cycles >= eq8);
                    assert!(out.stats.compute_cycles <= eq8 + (rows + cols) as u64);
                    assert_eq!(out.stats.readout_cycles, (rows * cols) as u64);
                }
            }
        }
    }
}

/// Back-to-back matmuls on one array must not leak state (the global
/// reset of §III-B).
#[test]
fn array_reset_between_runs() {
    let sa = SaConfig::new(2, 3, MacVariant::Booth);
    let mut arr = SystolicArray::new(sa);
    let a = [7i32, -3, 2, 5, -1, 4]; // 2×3
    let b = [1i32, 2, 3, -1, 0, 2, 1, 1, -2]; // 3×3
    let first = arr.matmul(&a, &b, 2, 3, 3, 4).unwrap().result;
    for _ in 0..3 {
        let again = arr.matmul(&a, &b, 2, 3, 3, 4).unwrap().result;
        assert_eq!(again, first);
    }
}

/// Mixed effective widths in consecutive runs — runtime-configurable
/// precision on the same hardware instance.
#[test]
fn runtime_precision_reconfiguration() {
    let sa = SaConfig::new(4, 4, MacVariant::Sbmwc);
    let mut arr = SystolicArray::new(sa);
    let mut rng = Pcg32::new(3);
    for &bits in &[1u32, 16, 2, 12, 7] {
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..4 * 5).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..5 * 4).map(|_| rng.range_i32(lo, hi)).collect();
        let out = arr.matmul(&a, &b, 4, 5, 4, bits).unwrap();
        assert_eq!(out.result, ref_matmul_i64(&a, &b, 4, 5, 4), "bits={bits}");
    }
}
