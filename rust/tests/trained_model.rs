//! The trained-workload integration: a genuinely trained (JAX/SGD)
//! quantized classifier, loaded from `artifacts/trained_mlp.txt` and
//! evaluated through the Rust serving stack — the accuracy the
//! simulated accelerator delivers must match the training-time
//! measurement. Skips when artifacts are absent.

use bitsmm::coordinator::{Backend, Scheduler};
use bitsmm::nn::weights_io::{evaluate, load_trained};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;

fn bundle_path() -> Option<std::path::PathBuf> {
    let dir = bitsmm::runtime::default_artifact_dir();
    let p = if dir.is_relative() {
        std::env::current_dir().ok()?.join(dir).join("trained_mlp.txt")
    } else {
        dir.join("trained_mlp.txt")
    };
    if p.exists() {
        Some(p)
    } else {
        eprintln!("[skip] no trained model at {} — run `make artifacts`", p.display());
        None
    }
}

#[test]
fn trained_accuracy_on_native_backend() {
    let Some(p) = bundle_path() else { return };
    let bundle = load_trained(&p).expect("parse trained bundle");
    assert!(bundle.float_acc > 0.9, "training failed upstream");
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let mut sched = Scheduler::new(sa, Backend::Native);
    let acc = evaluate(&bundle, &mut sched.as_exec()).expect("evaluate");
    // The Rust pipeline requantizes with the exported static scales
    // (python used per-batch dynamic scales), so allow a small gap.
    assert!(
        acc >= bundle.python_quant_acc - 0.05,
        "rust-served accuracy {acc} vs python {python}",
        python = bundle.python_quant_acc
    );
    assert!(acc > 0.85, "accelerator-delivered accuracy {acc}");
}

#[test]
fn trained_accuracy_identical_on_cycle_accurate_sim() {
    let Some(p) = bundle_path() else { return };
    let bundle = load_trained(&p).expect("parse trained bundle");
    // evaluate a subset on the (slow) cycle-accurate simulator and the
    // native path: identical logits, identical predictions
    let mut small = bundle.clone();
    small.eval_n = 32;
    small.eval_x.truncate(32 * small.eval_d);
    small.eval_y.truncate(32);
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let mut nat = Scheduler::new(sa, Backend::Native);
    let mut sim = Scheduler::new(sa, Backend::Simulate);
    let a1 = evaluate(&small, &mut nat.as_exec()).unwrap();
    let a2 = evaluate(&small, &mut sim.as_exec()).unwrap();
    assert_eq!(a1, a2, "native and cycle-accurate accuracies diverge");
    assert!(sim.report.hw_cycles > nat.report.hw_cycles / 2);
}

#[test]
fn per_layer_precisions_are_the_paper_style_mix() {
    let Some(p) = bundle_path() else { return };
    let bundle = load_trained(&p).expect("parse");
    let bits: Vec<u32> = bundle.model.layers.iter().map(|l| l.bits()).collect();
    assert_eq!(bits, vec![8, 4, 4], "per-layer precision mix");
}
