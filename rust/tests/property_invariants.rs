//! Property-based invariants over the coordinator and arithmetic
//! substrates, using the in-repo property-testing framework
//! (`proptest_lite`): routing/tiling coverage, quantization bounds,
//! Booth-digit reconstruction, simulator-vs-native agreement,
//! packed-plane/native/per-plane equality, and batching conservation.

use bitsmm::bits::booth::booth_digits;
use bitsmm::bits::packed::{
    matmul_packed_planes, matmul_packed_rsr, matmul_packed_tile_pooled,
    matmul_packed_tile_rowslice, matmul_packed_tile_stolen, matmul_packed_tile_stolen_with,
    matmul_packed_tile_with, KernelFamily, PackedPlanes, PackedPool, PopcountKernel, TilePolicy,
};
use bitsmm::bits::plane::{decompose, PlaneKind};
use bitsmm::bits::twos::{max_value, min_value, Bits};
use bitsmm::coordinator::tile_matmul;
use bitsmm::nn::quant::{dequantize, quantize_symmetric};
use bitsmm::nn::{matmul_native, matmul_packed, matmul_planes};
use bitsmm::plan::{ExecPlan, PlanKey, Planner, PlannerMode, ShapeRun};
use bitsmm::prng::Pcg32;
use bitsmm::proptest_lite::{forall, Gen};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::driver::{mac_dot, ref_matmul_i64, sa_matmul};
use bitsmm::sim::mac_common::MacVariant;

/// The four matmul realisations are pinned together: packed == native
/// == per-plane == the i64 reference, for random shapes (k straddling
/// the 64-digit word boundary) and every width 1..=16.
#[test]
fn prop_packed_native_planes_reference_agree() {
    let gen = Gen::pair(
        Gen::pair(Gen::u32s(1, 16), Gen::u32s(0, u32::MAX)), // (bits, seed)
        Gen::pair(Gen::u32s(1, 5), Gen::pair(Gen::u32s(1, 140), Gen::u32s(1, 6))), // (m, (k, n))
    );
    forall("packed==native==planes==ref", 80, gen, |&((bits, seed), (m, (k, n)))| {
        let (m, k, n) = (m as usize, k as usize, n as usize);
        let mut rng = Pcg32::new(seed as u64 ^ 0x9e3779b97f4a7c15);
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
        let want = ref_matmul_i64(&a, &b, m, k, n);
        matmul_packed(&a, &b, m, k, n, bits).unwrap() == want
            && matmul_native(&a, &b, m, k, n, bits).unwrap() == want
            && matmul_planes(&a, &b, m, k, n, bits).unwrap() == want
    });
}

/// Pack → unpack reproduces the decomposition oracle's digit planes
/// exactly, for both plane kinds and lengths crossing word boundaries.
#[test]
fn prop_packed_roundtrip_matches_decompose_oracle() {
    let gen = Gen::pair(
        Gen::pair(Gen::u32s(1, 16), Gen::u32s(1, 200)), // (bits, len)
        Gen::u32s(0, u32::MAX),                         // seed
    );
    forall("pack/unpack == decompose", 120, gen, |&((bits, len), seed)| {
        let mut rng = Pcg32::new(seed as u64);
        let (lo, hi) = (min_value(bits), max_value(bits));
        let data: Vec<i32> = (0..2 * len as usize).map(|_| rng.range_i32(lo, hi)).collect();
        [PlaneKind::Sbmwc, PlaneKind::Booth].iter().all(|&kind| {
            let p = PackedPlanes::pack_rows(&data, 2, len as usize, bits, kind).unwrap();
            p.unpack() == decompose(kind, &data, bits)
        })
    });
}

/// The SBMwC sign-plane correction and the tail-word masking are exact
/// at the extremes: operands saturated at the width's min/max, with k
/// straddling the 64-digit word boundary in every direction.
#[test]
fn packed_sign_plane_and_tail_word_edges() {
    for bits in 1..=16u32 {
        let (m, n) = (2usize, 3usize);
        for k in [1usize, 63, 64, 65, 70, 128, 129] {
            for fill in [min_value(bits), max_value(bits)] {
                let a = vec![fill; m * k];
                let mut b = vec![fill; k * n];
                // perturb one element so the product is not uniform
                b[k / 2 * n] = 0;
                let want = ref_matmul_i64(&a, &b, m, k, n);
                assert_eq!(
                    matmul_packed(&a, &b, m, k, n, bits).unwrap(),
                    want,
                    "bits={bits} k={k} fill={fill}"
                );
                // mixed-kind kernels hit the same reference
                let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Booth).unwrap();
                let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
                assert_eq!(matmul_packed_planes(&pa, &pb).unwrap(), want, "booth x sbmwc bits={bits} k={k}");
            }
        }
    }
}

/// The threaded row-block kernel, the single-thread kernel (forced
/// scalar — the PR 1 reducer), every unroll/SIMD reducer, and the
/// native loop agree bit-for-bit for widths 1..=16 under both MAC
/// variants' plane kinds.
#[test]
fn threaded_equals_single_thread_equals_native_all_widths() {
    let pool = PackedPool::new(4).unwrap();
    let mut rng = Pcg32::new(0x7bea17);
    for bits in 1..=16u32 {
        let (lo, hi) = (min_value(bits), max_value(bits));
        // m both below and above the pool width; k straddles a word
        for (m, k, n) in [(2usize, 70usize, 3usize), (11, 64, 2)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
            let want = ref_matmul_i64(&a, &b, m, k, n);
            assert_eq!(matmul_native(&a, &b, m, k, n, bits).unwrap(), want);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let pa = std::sync::Arc::new(
                    PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap(),
                );
                let pb = std::sync::Arc::new(
                    PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap(),
                );
                let serial =
                    matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar)
                        .unwrap();
                assert_eq!(serial, want, "{kind:?} scalar bits={bits} {m}x{k}x{n}");
                for kernel in PopcountKernel::CONCRETE {
                    assert_eq!(
                        matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, kernel).unwrap(),
                        want,
                        "{kind:?} {} bits={bits}",
                        kernel.name()
                    );
                }
                let pooled = matmul_packed_tile_pooled(
                    &pool,
                    &pa,
                    &pb,
                    0,
                    m,
                    0,
                    n,
                    PopcountKernel::Auto,
                )
                .unwrap();
                assert_eq!(pooled, want, "{kind:?} pooled bits={bits} {m}x{k}x{n}");
            }
        }
    }
}

/// The work-stealing 2-D tile scheduler is bit-identical to the serial
/// kernel, the equal-row-slice PR 2 partitioner, and the native loop
/// across every width 1..=16, both plane kinds, and the skewed shapes
/// the scheduler exists for (single-row, single-column, wide-K),
/// including tail-word k values — under tiling policies that force
/// maximal tile counts and steal traffic.
#[test]
fn stolen_2d_tiles_equal_serial_and_native_all_widths() {
    let pool = PackedPool::new(4).unwrap();
    let mut rng = Pcg32::new(0x2d_713e);
    for bits in 1..=16u32 {
        let (lo, hi) = (min_value(bits), max_value(bits));
        // tall-thin, wide-short, and a 2-D shape; k straddles words
        for (m, k, n) in [(1usize, 65usize, 23usize), (23, 63, 1), (7, 128, 9)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
            let want = ref_matmul_i64(&a, &b, m, k, n);
            assert_eq!(matmul_native(&a, &b, m, k, n, bits).unwrap(), want);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let pa = std::sync::Arc::new(
                    PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap(),
                );
                let pb = std::sync::Arc::new(
                    PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap(),
                );
                let serial =
                    matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar)
                        .unwrap();
                assert_eq!(serial, want, "{kind:?} serial bits={bits} {m}x{k}x{n}");
                let rowslice = matmul_packed_tile_rowslice(
                    &pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto,
                )
                .unwrap();
                assert_eq!(rowslice, want, "{kind:?} rowslice bits={bits} {m}x{k}x{n}");
                for policy in [
                    TilePolicy::AUTO,
                    TilePolicy { tile_rows: 1, tile_cols: 1, ..TilePolicy::AUTO },
                    TilePolicy { tile_rows: 0, tile_cols: 2, ..TilePolicy::AUTO },
                    TilePolicy { tile_rows: 3, tile_cols: 0, ..TilePolicy::AUTO },
                ] {
                    let (stolen, stats) = matmul_packed_tile_stolen(
                        &pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto, policy,
                    )
                    .unwrap();
                    assert_eq!(
                        stolen, want,
                        "{kind:?} stolen bits={bits} {m}x{k}x{n} {policy:?}"
                    );
                    assert!(stats.tiles >= 1);
                    assert!(stats.max_worker_tiles >= stats.min_worker_tiles);
                }
            }
        }
    }
}

/// Sign-plane and tail-word edges under the stolen scheduler: operands
/// saturated at the width's minimum make the SBMwC MSb (sign) plane
/// all-ones, and k values straddle the 64-digit word boundary — the
/// stolen tiling must not disturb either correction.
#[test]
fn stolen_tiling_sign_plane_and_tail_word_edges() {
    let pool = PackedPool::new(3).unwrap();
    for bits in [1u32, 2, 8, 16] {
        let (m, n) = (1usize, 5usize); // single-row: pure column tiling
        for k in [1usize, 63, 64, 65, 129] {
            let fill = min_value(bits);
            let a = vec![fill; m * k];
            let mut b = vec![fill; k * n];
            b[k / 2 * n] = 0; // non-uniform product
            let want = ref_matmul_i64(&a, &b, m, k, n);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let pa = std::sync::Arc::new(
                    PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap(),
                );
                let pb = std::sync::Arc::new(
                    PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap(),
                );
                let (stolen, _) = matmul_packed_tile_stolen(
                    &pool,
                    &pa,
                    &pb,
                    0,
                    m,
                    0,
                    n,
                    PopcountKernel::Auto,
                    TilePolicy { tile_rows: 1, tile_cols: 2, ..TilePolicy::AUTO },
                )
                .unwrap();
                assert_eq!(stolen, want, "{kind:?} bits={bits} k={k}");
            }
        }
    }
}

/// Random tile policies never change the integers: for arbitrary
/// shapes, widths, and (tile_rows, tile_cols) knob values — including
/// 0 (auto) and values larger than the shape — the stolen scheduler
/// reproduces the serial kernel exactly.
#[test]
fn prop_stolen_tiling_bit_identical_for_any_policy() {
    let pool = PackedPool::new(3).unwrap();
    let gen = Gen::pair(
        Gen::pair(Gen::u32s(1, 16), Gen::u32s(0, u32::MAX)), // (bits, seed)
        Gen::pair(
            Gen::pair(Gen::u32s(1, 9), Gen::pair(Gen::u32s(1, 140), Gen::u32s(1, 40))), // (m,(k,n))
            Gen::pair(Gen::u32s(0, 12), Gen::u32s(0, 48)), // (tile_rows, tile_cols)
        ),
    );
    forall("stolen == serial for any policy", 60, gen, |&((bits, seed), ((m, (k, n)), (tr, tc)))| {
        let (m, k, n) = (m as usize, k as usize, n as usize);
        let mut rng = Pcg32::new(seed as u64 ^ 0x2d7);
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
        let pa = std::sync::Arc::new(
            PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap(),
        );
        let pb = std::sync::Arc::new(
            PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Booth).unwrap(),
        );
        let serial =
            matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar).unwrap();
        let policy = TilePolicy { tile_rows: tr as usize, tile_cols: tc as usize, ..TilePolicy::AUTO };
        let (stolen, stats) =
            matmul_packed_tile_stolen(&pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto, policy)
                .unwrap();
        serial == ref_matmul_i64(&a, &b, m, k, n)
            && stolen == serial
            && stats.max_worker_tiles >= stats.min_worker_tiles
    });
}

/// The RSR segment kernel (PR 6) is bit-identical to the serial packed
/// oracle and the native reference for **every** width 1..=16, both
/// plane kinds, skewed shapes with tail-word k, and every seg_words
/// choice (auto, single-word, multi-word, longer than the operand) —
/// on both uniform-random operands (RSR's worst case, where segment
/// dedup finds almost nothing to share) and codebook-redundant columns
/// (its target regime). Segment reuse is a pure re-association of the
/// same exact i64 dot, so speed may change but integers never do.
#[test]
fn rsr_segment_kernel_equals_serial_and_native_all_widths() {
    let mut rng = Pcg32::new(0x5e6_2024);
    for bits in 1..=16u32 {
        let (lo, hi) = (min_value(bits), max_value(bits));
        // tall-thin, small 2-D, and word-boundary-straddling k
        for (m, k, n) in [(1usize, 65usize, 23usize), (6, 70, 9), (3, 129, 17)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
            // codebook-redundant stationary operand: 4 distinct columns
            // repeated — the regime segment dedup exists for
            let book: Vec<Vec<i32>> =
                (0..4).map(|_| (0..k).map(|_| rng.range_i32(lo, hi)).collect()).collect();
            let mut b = vec![0i32; k * n];
            for j in 0..n {
                for (r, &v) in book[j % 4].iter().enumerate() {
                    b[r * n + j] = v;
                }
            }
            let want = ref_matmul_i64(&a, &b, m, k, n);
            assert_eq!(matmul_native(&a, &b, m, k, n, bits).unwrap(), want);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let pa = PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap();
                let pb = PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap();
                let serial =
                    matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar)
                        .unwrap();
                assert_eq!(serial, want, "{kind:?} serial oracle bits={bits}");
                for seg_words in [0usize, 1, 2, 3, 64] {
                    let got = matmul_packed_rsr(
                        &pa, &pb, 0, m, 0, n, PopcountKernel::Auto, seg_words,
                    )
                    .unwrap();
                    assert_eq!(
                        got, want,
                        "{kind:?} rsr seg_words={seg_words} bits={bits} {m}x{k}x{n}"
                    );
                }
            }
        }
    }
    // sign-plane saturation: operands pinned at the width's min/max
    // make the SBMwC sign plane all-ones; segment dedup then collapses
    // every column to one pattern — the maximal-sharing edge
    for bits in 1..=16u32 {
        let (m, n) = (2usize, 5usize);
        for k in [1usize, 63, 64, 65, 129] {
            for fill in [min_value(bits), max_value(bits)] {
                let a = vec![fill; m * k];
                let mut b = vec![fill; k * n];
                b[k / 2 * n] = 0; // non-uniform product
                let want = ref_matmul_i64(&a, &b, m, k, n);
                for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    let pa = PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap();
                    let pb = PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap();
                    for seg_words in [0usize, 1, 2] {
                        assert_eq!(
                            matmul_packed_rsr(&pa, &pb, 0, m, 0, n, PopcountKernel::Auto, seg_words)
                                .unwrap(),
                            want,
                            "{kind:?} rsr bits={bits} k={k} fill={fill} seg_words={seg_words}"
                        );
                    }
                }
            }
        }
    }
}

/// The deterministic k-split (PR 6) is bit-identical to the serial
/// packed oracle and the native reference for every width 1..=16, both
/// plane kinds, and forced chunk counts that do **not** divide the
/// word count — including tail-word k, chunk counts exceeding the
/// words (clamped), sign-saturated operands, and the RSR family riding
/// the same stolen scheduler (where k-split is defined to clamp to 1).
#[test]
fn ksplit_stolen_tiles_equal_serial_and_native_all_widths() {
    let pool = PackedPool::new(3).unwrap();
    let mut rng = Pcg32::new(0x6b5_2024);
    for bits in 1..=16u32 {
        let (lo, hi) = (min_value(bits), max_value(bits));
        // 1×hugek×n (the shape k-split exists for), a 2-D shape, and a
        // single-word k that any chunk count must clamp against; k=257
        // and k=200 leave tail words not divisible by the chunk counts
        for (m, k, n) in [(1usize, 257usize, 23usize), (5, 200, 3), (2, 64, 2)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
            let want = ref_matmul_i64(&a, &b, m, k, n);
            assert_eq!(matmul_native(&a, &b, m, k, n, bits).unwrap(), want);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let pa = std::sync::Arc::new(
                    PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap(),
                );
                let pb = std::sync::Arc::new(
                    PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap(),
                );
                let serial =
                    matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar)
                        .unwrap();
                assert_eq!(serial, want, "{kind:?} serial oracle bits={bits}");
                for k_chunks in [0usize, 1, 2, 3, 5, 7] {
                    let policy = TilePolicy { k_chunks, ..TilePolicy::AUTO };
                    let (got, stats) = matmul_packed_tile_stolen_with(
                        &pool, &pa, &pb, 0, m, 0, n,
                        PopcountKernel::Auto, policy, KernelFamily::Popcount,
                    )
                    .unwrap();
                    assert_eq!(
                        got, want,
                        "{kind:?} k_chunks={k_chunks} bits={bits} {m}x{k}x{n}"
                    );
                    assert!(stats.tiles >= 1);
                }
                // RSR through the stolen executor under a forced-split
                // policy: the scheduler must clamp the split to 1 and
                // still match
                let (rsr, _) = matmul_packed_tile_stolen_with(
                    &pool, &pa, &pb, 0, m, 0, n,
                    PopcountKernel::Auto,
                    TilePolicy { k_chunks: 3, ..TilePolicy::AUTO },
                    KernelFamily::Rsr { seg_words: 0 },
                )
                .unwrap();
                assert_eq!(rsr, want, "{kind:?} stolen rsr bits={bits} {m}x{k}x{n}");
            }
        }
    }
    // sign-plane saturation under forced k-splits: the per-chunk
    // partials each carry a slice of the all-ones sign plane; their
    // fixed-order merge must reproduce the correction exactly
    let pool2 = PackedPool::new(2).unwrap();
    for bits in [1u32, 2, 8, 16] {
        let (m, n) = (1usize, 4usize);
        for k in [65usize, 129, 257] {
            let fill = min_value(bits);
            let a = vec![fill; m * k];
            let mut b = vec![fill; k * n];
            b[k / 2 * n] = 0;
            let want = ref_matmul_i64(&a, &b, m, k, n);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let pa = std::sync::Arc::new(
                    PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap(),
                );
                let pb = std::sync::Arc::new(
                    PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap(),
                );
                for k_chunks in [2usize, 3] {
                    let (got, _) = matmul_packed_tile_stolen_with(
                        &pool2, &pa, &pb, 0, m, 0, n,
                        PopcountKernel::Auto,
                        TilePolicy { k_chunks, ..TilePolicy::AUTO },
                        KernelFamily::Popcount,
                    )
                    .unwrap();
                    assert_eq!(got, want, "{kind:?} bits={bits} k={k} k_chunks={k_chunks}");
                }
            }
        }
    }
}

/// Planner bit-transparency: **every** candidate `ExecPlan` — all
/// available popcount kernels × serial/pooled × rowslice/stolen ×
/// forced tile policies × native/packed — produces bit-identical
/// output over widths 1..=16, both plane kinds, and the skewed shapes
/// the planner exists for. Plans may change speed, never results:
/// this is the invariant that makes the planner safe to drop into the
/// serving path.
#[test]
fn every_candidate_plan_is_bit_transparent_all_widths() {
    let pool = std::sync::Arc::new(PackedPool::new(2).unwrap());
    let candidates = ExecPlan::candidates(pool.threads() + 1);
    assert!(candidates.len() >= 5, "candidate space unexpectedly small");
    let mut rng = Pcg32::new(0x914a);
    for bits in 1..=16u32 {
        let (lo, hi) = (min_value(bits), max_value(bits));
        // tall-thin, wide-short, word-boundary k — the skew set
        for (m, k, n) in [(1usize, 65usize, 17usize), (17, 63, 1), (5, 128, 7)] {
            let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
            let want = ref_matmul_i64(&a, &b, m, k, n);
            // the serial packed oracle agrees with the native reference
            assert_eq!(matmul_native(&a, &b, m, k, n, bits).unwrap(), want);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let pb = std::sync::Arc::new(
                    PackedPlanes::pack_cols(&b, k, n, bits, kind).unwrap(),
                );
                let serial = matmul_packed_tile_with(
                    &PackedPlanes::pack_rows(&a, m, k, bits, kind).unwrap(),
                    &pb,
                    0,
                    m,
                    0,
                    n,
                    PopcountKernel::Scalar,
                )
                .unwrap();
                assert_eq!(serial, want, "{kind:?} serial oracle bits={bits}");
                let run = ShapeRun {
                    a: &a,
                    b: &b,
                    m,
                    k,
                    n,
                    bits,
                    stream_kind: kind,
                    packed_b: Some(&pb),
                    pool: Some(&pool),
                };
                for plan in &candidates {
                    let (out, _, _) = run.run(plan).unwrap();
                    assert_eq!(
                        out,
                        want,
                        "{} diverged ({kind:?} {m}x{k}x{n} @{bits}b)",
                        plan.label()
                    );
                }
            }
        }
    }
}

/// Planner resolution is bit-transparent end to end: whatever tier a
/// plan comes from (cost model, nearest bucket, loaded plan file, or
/// on-line calibration), executing it reproduces the reference
/// integers — including after a save → load round trip of the plan
/// cache.
#[test]
fn planner_resolutions_roundtrip_and_stay_exact() {
    let pool = std::sync::Arc::new(PackedPool::new(2).unwrap());
    let planner = Planner::new(PlannerMode::Online, pool.threads() + 1);
    let mut rng = Pcg32::new(0x914b);
    let shapes = [(1usize, 70usize, 33usize, 3u32), (9, 64, 9, 8), (4, 129, 2, 16)];
    for &(m, k, n, bits) in &shapes {
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
        let want = ref_matmul_i64(&a, &b, m, k, n);
        let run = ShapeRun {
            a: &a,
            b: &b,
            m,
            k,
            n,
            bits,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: Some(&pool),
        };
        let key = PlanKey::for_matmul(m, k, n, bits, bits, PlaneKind::Sbmwc);
        let (_, _, out) = planner.plan_run(key, &run).unwrap();
        assert_eq!(out.expect("first touch calibrates").0, want, "{m}x{k}x{n}@{bits}b");
    }
    // round-trip the cache and check the loaded plans still execute
    // to the same integers
    let path = std::env::temp_dir().join("bitsmm_prop_plans.json");
    planner.save_file(&path).unwrap();
    let loaded = Planner::new(PlannerMode::Static, pool.threads() + 1);
    assert_eq!(loaded.load_file(&path).unwrap(), planner.len());
    for &(m, k, n, bits) in &shapes {
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
        let key = PlanKey::for_matmul(m, k, n, bits, bits, PlaneKind::Sbmwc);
        let (plan, tier) = loaded.resolve(key);
        assert_eq!(tier, bitsmm::plan::PlanTier::Exact, "loaded plans hit exactly");
        assert_eq!(plan, planner.peek(&key).unwrap(), "round trip preserved the plan");
        let run = ShapeRun {
            a: &a,
            b: &b,
            m,
            k,
            n,
            bits,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: Some(&pool),
        };
        let (out, _, _) = run.run(&plan).unwrap();
        assert_eq!(out, ref_matmul_i64(&a, &b, m, k, n));
    }
    std::fs::remove_file(&path).unwrap();
}

/// Cross-precision plane slicing is exact: a `b'`-bit slice of a
/// `b`-bit pack equals a fresh re-pack at `b'` (same planes, same
/// matmul integers) for every legal `(b, b')` pair, both plane kinds,
/// sign-plane-saturated operands, and k straddling word boundaries.
#[test]
fn prop_cross_precision_slice_equals_repack() {
    let gen = Gen::pair(
        Gen::pair(Gen::u32s(2, 16), Gen::u32s(0, u32::MAX)), // (hi bits, seed)
        Gen::pair(Gen::u32s(1, 140), Gen::u32s(1, 15)),      // (k, lo hint)
    );
    forall("slice == repack", 100, gen, |&((hi, seed), (k, lo_hint))| {
        let lo = 1 + lo_hint % (hi - 1); // 1..=hi-1, strictly narrower
        let (m, k) = (3usize, k as usize);
        let mut rng = Pcg32::new(seed as u64 ^ 0x51ce);
        let data: Vec<i32> = (0..m * k)
            .map(|_| rng.range_i32(min_value(lo), max_value(lo)))
            .collect();
        [PlaneKind::Sbmwc, PlaneKind::Booth].iter().all(|&kind| {
            let wide = PackedPlanes::pack_rows(&data, m, k, hi, kind).unwrap();
            let fresh = PackedPlanes::pack_rows(&data, m, k, lo, kind).unwrap();
            wide.slice_bits(lo).unwrap() == fresh
        })
    });
}

/// Slice edges: saturated sign planes and word-boundary tails, plus
/// sliced operands inside a full matmul, plus the `min_bits` guard.
#[test]
fn cross_precision_slice_sign_plane_and_tail_word_edges() {
    for hi in 2..=16u32 {
        for lo in 1..hi {
            let (m, n) = (2usize, 2usize);
            for k in [1usize, 63, 64, 65, 130] {
                for fill in [min_value(lo), max_value(lo)] {
                    let a = vec![fill; m * k];
                    let mut b = vec![fill; k * n];
                    b[k / 2 * n] = 0; // non-uniform product
                    let want = ref_matmul_i64(&a, &b, m, k, n);
                    for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                        let pa = PackedPlanes::pack_rows(&a, m, k, hi, kind)
                            .unwrap()
                            .slice_bits(lo)
                            .unwrap();
                        let pb = PackedPlanes::pack_cols(&b, k, n, hi, kind)
                            .unwrap()
                            .slice_bits(lo)
                            .unwrap();
                        assert_eq!(pa, PackedPlanes::pack_rows(&a, m, k, lo, kind).unwrap());
                        assert_eq!(
                            matmul_packed_planes(&pa, &pb).unwrap(),
                            want,
                            "{kind:?} {hi}->{lo} k={k} fill={fill}"
                        );
                    }
                }
            }
        }
    }
    // the guard: values needing `hi` bits refuse to slice narrower
    for hi in 2..=16u32 {
        let data = vec![min_value(hi); 4];
        let p = PackedPlanes::pack_rows(&data, 2, 2, hi, PlaneKind::Sbmwc).unwrap();
        assert_eq!(p.min_bits, hi);
        assert!(p.slice_bits(hi - 1).is_err(), "hi={hi}");
    }
}

/// Tiling covers every output element exactly once, for arbitrary
/// problem and array geometries.
#[test]
fn prop_tiler_partitions_output() {
    let gen = Gen::pair(
        Gen::pair(Gen::u32s(1, 40), Gen::u32s(1, 40)), // (m, n)
        Gen::pair(Gen::u32s(1, 9), Gen::u32s(1, 17)),  // (rows, cols)
    );
    forall("tiler partitions output", 300, gen, |&((m, n), (rows, cols))| {
        let sa = SaConfig::new(rows as usize, cols as usize, MacVariant::Booth);
        let plan = tile_matmul(m as usize, 3, n as usize, &sa);
        let mut cover = vec![0u32; (m * n) as usize];
        for j in &plan.jobs {
            if j.m > rows as usize || j.n > cols as usize {
                return false;
            }
            for r in j.row0..j.row0 + j.m {
                for c in j.col0..j.col0 + j.n {
                    cover[r * n as usize + c] += 1;
                }
            }
        }
        cover.iter().all(|&x| x == 1)
    });
}

/// Quantization always lands inside the two's-complement range and the
/// reconstruction error is bounded by half a step.
#[test]
fn prop_quantization_bounds() {
    let gen = Gen::pair(Gen::u32s(1, 16), Gen::vecs(Gen::i32s(-1000, 1000), 1, 64));
    forall("quantization bounds", 300, gen, |(bits, raw)| {
        let x: Vec<f64> = raw.iter().map(|&v| v as f64 / 37.0).collect();
        let t = match quantize_symmetric(&x, vec![x.len()], *bits) {
            Ok(t) => t,
            Err(_) => return false,
        };
        let in_range = t
            .data
            .iter()
            .all(|&q| q >= min_value(*bits) && q <= max_value(*bits));
        // reconstruction error bounded by half a step for values the
        // grid can represent; symmetric quantization clamps the extreme
        // positive value (|max| = |min|−1 step), so allow a full step
        let xr = dequantize(&t);
        let bounded = x
            .iter()
            .zip(&xr)
            .all(|(a, b)| (a - b).abs() <= t.scale + 1e-9);
        in_range && bounded
    });
}

/// Booth digits always reconstruct the value (Table I identity) and
/// contain no digit runs of equal nonzero sign without a gap — the
/// structural property that bounds adder activity.
#[test]
fn prop_booth_digits_reconstruct() {
    let gen = Gen::pair(Gen::u32s(1, 16), Gen::i32s(-32768, 32767));
    forall("booth digits reconstruct", 500, gen, |&(bits, v)| {
        let v = v.clamp(min_value(bits), max_value(bits));
        let b = Bits::new(v, bits).unwrap();
        let digits = booth_digits(b);
        let sum: i64 = digits.iter().enumerate().map(|(i, &d)| (d as i64) << i).sum();
        let no_adjacent_same_sign = digits
            .windows(2)
            .all(|w| !(w[0] != 0 && w[1] != 0 && w[0] == w[1]));
        sum == v as i64 && no_adjacent_same_sign
    });
}

/// The three functional paths agree: reference integer matmul, native
/// Booth-plane matmul, and the cycle-accurate simulator.
#[test]
fn prop_backends_agree() {
    let gen = Gen::pair(
        Gen::pair(Gen::u32s(1, 4), Gen::pair(Gen::u32s(1, 9), Gen::u32s(1, 6))),
        Gen::pair(Gen::u32s(1, 8), Gen::u32s(0, u32::MAX)),
    );
    forall("backends agree", 60, gen, |&((m, (k, n)), (bits, seed))| {
        let mut rng = Pcg32::new(seed as u64);
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..(m * k) as usize).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..(k * n) as usize).map(|_| rng.range_i32(lo, hi)).collect();
        let (m, k, n) = (m as usize, k as usize, n as usize);
        let reference = ref_matmul_i64(&a, &b, m, k, n);
        let native = matmul_native(&a, &b, m, k, n, bits).unwrap();
        let sa = SaConfig::new(m, n, MacVariant::Booth);
        let sim = sa_matmul(sa, &a, &b, m, k, n, bits).unwrap().result;
        native == reference && sim == reference
    });
}

/// Single-MAC dot products satisfy eq. 8 cycle counts for every
/// (length, width) pair.
#[test]
fn prop_eq8_cycles_exact() {
    let gen = Gen::pair(Gen::u32s(1, 16), Gen::u32s(1, 64));
    forall("eq8 exact", 120, gen, |&(bits, len)| {
        // {0, −1} fits every width including 1-bit
        let mc: Vec<i32> = (0..len as usize).map(|i| -((i as i32) % 2)).collect();
        let ml = mc.clone();
        let (_, cycles) = mac_dot(MacVariant::Booth, &mc, &ml, bits, 48);
        cycles == (len as u64 + 1) * bits as u64
    });
}

/// Accumulator wrapping is consistent between variants: both wrap to
/// the same register-width semantics.
#[test]
fn prop_wrapping_consistent_between_variants() {
    let gen = Gen::pair(Gen::u32s(8, 20), Gen::u32s(0, u32::MAX));
    forall("wrap consistent", 80, gen, |&(acc_bits, seed)| {
        let mut rng = Pcg32::new(seed as u64);
        let mc: Vec<i32> = (0..12).map(|_| rng.range_i32(-128, 127)).collect();
        let ml: Vec<i32> = (0..12).map(|_| rng.range_i32(-128, 127)).collect();
        let (a, _) = mac_dot(MacVariant::Booth, &mc, &ml, 8, acc_bits);
        let (b, _) = mac_dot(MacVariant::Sbmwc, &mc, &ml, 8, acc_bits);
        a == b
    });
}
