//! End-to-end flight-telemetry integration (DESIGN.md §Observability):
//! a real packed serving run with the snapshotter and the request
//! tracer armed must leave behind
//!
//!   * a JSONL metrics file with ≥ 2 snapshots (periodic + final) that
//!     round-trips through the in-repo JSON reader with every counter
//!     group present, and
//!   * a JSONL trace dump whose spans cover every request from
//!     `admit` to `respond` with per-trace monotone sequence numbers —
//!
//! exactly what CI's `bitsmm obs` gate consumes instead of grepping
//! report tables.

use bitsmm::coordinator::{Backend, InferenceServer, Request, ServerConfig};
use bitsmm::obs::snapshot::{check_snapshot_file, lookup, parse_snapshots, REQUIRED_GROUPS};
use bitsmm::plan::store::Json;
use bitsmm::prng::Pcg32;
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn inputs(n: usize, d: usize, bits: u32) -> Vec<Vec<i32>> {
    let mut rng = Pcg32::new(0x7e1e);
    let lo = bitsmm::bits::twos::min_value(bits);
    let hi = bitsmm::bits::twos::max_value(bits);
    (0..n)
        .map(|_| (0..d).map(|_| rng.range_i32(lo, hi)).collect())
        .collect()
}

#[test]
fn serving_run_round_trips_snapshots_and_request_traces() {
    let dir = std::env::temp_dir().join(format!("bitsmm_telemetry_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let metrics_path = dir.join("metrics.jsonl");
    let trace_path = dir.join("trace.jsonl");

    let model = Arc::new(bitsmm::nn::model::mlp_zoo(5));
    let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
    cfg.workers = 2;
    cfg.packed_threads = 2;
    cfg.metrics_file = Some(metrics_path.clone());
    cfg.metrics_every_ms = 5;
    cfg.trace_file = Some(trace_path.clone());
    let server = InferenceServer::start(model, cfg).unwrap();
    let n = 10usize;
    let rxs: Vec<_> = inputs(n, 64, 8)
        .into_iter()
        .enumerate()
        .map(|(i, x)| server.submit(Request::new(i as u64, x)))
        .collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().output.is_ok());
    }
    // give the snapshotter a couple of periods beyond the initial write
    std::thread::sleep(Duration::from_millis(25));
    let (_, metrics) = server.shutdown();
    assert_eq!(metrics.requests as usize, n);

    // --- snapshots: parse, groups, final aggregate -------------------
    let text = std::fs::read_to_string(&metrics_path).unwrap();
    let snaps = parse_snapshots(&text).unwrap();
    assert!(snaps.len() >= 2, "periodic + final expected, got {}", snaps.len());
    let last = snaps.last().unwrap();
    assert_eq!(lookup(last, "final").unwrap(), &Json::Bool(true));
    assert_eq!(lookup(last, "requests").unwrap().as_int().unwrap() as usize, n);
    assert_eq!(lookup(last, "latency.count").unwrap().as_int().unwrap() as usize, n);
    for g in REQUIRED_GROUPS {
        assert!(lookup(last, g).is_ok(), "counter group {g} missing from the snapshot");
    }
    // every snapshot field CI gates on is finite-or-null by contract:
    // re-rendering the parsed line must not find a bare inf/nan token
    for line in text.lines() {
        assert!(
            !line.contains("inf") && !line.contains("NaN"),
            "non-finite leaked into JSONL: {line}"
        );
    }

    // --- the CI gate itself ------------------------------------------
    let summary = check_snapshot_file(
        &metrics_path,
        "faults.unmasked=0, errors=0, latency.count>=10, scrub.repaired>=0",
    )
    .unwrap();
    assert!(summary.contains("4 requirements"), "{summary}");
    // a violated requirement must fail loudly, not pass silently
    assert!(check_snapshot_file(&metrics_path, "errors>=1").is_err());

    // --- traces: every request admit→…→respond, monotone seq ---------
    let ttext = std::fs::read_to_string(&trace_path).unwrap();
    let mut per_trace: HashMap<i64, Vec<(i64, String)>> = HashMap::new();
    for line in ttext.lines() {
        let v = Json::parse(line).unwrap();
        if v.field("capacity").is_ok() {
            // the ring-accounting trailer: nothing may have been dropped
            assert_eq!(v.field("dropped").unwrap().as_int().unwrap(), 0);
            continue;
        }
        per_trace
            .entry(v.field("trace").unwrap().as_int().unwrap())
            .or_default()
            .push((
                v.field("seq").unwrap().as_int().unwrap(),
                v.field("kind").unwrap().as_str().unwrap().to_string(),
            ));
    }
    assert_eq!(per_trace.len(), n, "one trace per request");
    let mut all_kinds = std::collections::HashSet::new();
    for (trace, spans) in &per_trace {
        assert!(
            spans.windows(2).all(|p| p[0].0 < p[1].0),
            "trace {trace}: span seq not monotone"
        );
        let kinds: Vec<&str> = spans.iter().map(|(_, k)| k.as_str()).collect();
        assert_eq!(kinds.first().copied(), Some("admit"), "trace {trace}: {kinds:?}");
        assert_eq!(kinds.last().copied(), Some("respond"), "trace {trace}: {kinds:?}");
        assert!(kinds.contains(&"queue_wait"), "trace {trace}: {kinds:?}");
        all_kinds.extend(kinds.iter().map(|k| k.to_string()));
    }
    // the packed execution stages land on each batch's lead trace
    for stage in ["assemble", "pack_slice", "plan_resolve", "kernel"] {
        assert!(all_kinds.contains(stage), "no {stage} span anywhere in the dump");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn telemetry_is_off_by_default_and_leaves_no_files() {
    let dir = std::env::temp_dir().join(format!("bitsmm_telemetry_off_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = Arc::new(bitsmm::nn::model::mlp_zoo(5));
    let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
    cfg.workers = 1;
    cfg.packed_threads = 2;
    let server = InferenceServer::start(model, cfg).unwrap();
    let rxs: Vec<_> = inputs(4, 64, 8)
        .into_iter()
        .enumerate()
        .map(|(i, x)| server.submit(Request::new(i as u64, x)))
        .collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().output.is_ok());
    }
    let (_, metrics) = server.shutdown();
    assert_eq!(metrics.requests, 4);
    let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert!(leftovers.is_empty(), "telemetry wrote files while disabled");
    std::fs::remove_dir_all(&dir).unwrap();
}
