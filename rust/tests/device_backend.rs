//! Device-backend properties: the streamed instruction-driven driver
//! (DESIGN.md §Device) must be *bit-identical* to the native and
//! packed matmul paths across the full precision range, both MAC
//! variants, and skewed shapes — and its cycle accounting must
//! reproduce the pre-refactor simulator exactly (streaming the
//! operands through the DMA transport is a transport change, not a
//! timing change).

use bitsmm::bits::twos::{max_value, min_value};
use bitsmm::coordinator::{serve_all, shaped_inputs, tile_matmul, Backend, ServerConfig};
use bitsmm::device::device_matmul;
use bitsmm::nn::model::zoo_model;
use bitsmm::nn::{matmul_native, matmul_packed};
use bitsmm::prng::Pcg32;
use bitsmm::sim::array::{SaConfig, SystolicArray};
use bitsmm::sim::mac_common::MacVariant;
use std::sync::Arc;

fn rand_operands(m: usize, k: usize, n: usize, bits: u32, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let (lo, hi) = (min_value(bits), max_value(bits));
    let mut rng = Pcg32::new(seed);
    let a = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
    let b = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
    (a, b)
}

/// The closed-form compute-cycle count the pre-refactor simulator
/// measured for one tile: every edge source runs `delay + pattern`
/// cycles — unused columns idle through their skew (`cols-1`), unused
/// rows through skew + lead (`rows-1+bits`), used columns stream
/// `k+1` operands of `bits` each after their skew (`n-1 + bits(k+1)`,
/// the +1 is the flush operand that latches the last value), and used
/// rows stream `k` operands after skew + lead (`m-1 + bits(k+1)`).
fn pre_refactor_exec_cycles(sa: &SaConfig, m: usize, n: usize, k: usize, bits: u32) -> u64 {
    let b = bits as u64;
    let stream = b * (k as u64 + 1);
    [
        sa.cols as u64 - 1,
        sa.rows as u64 - 1 + b,
        stream + m as u64 - 1,
        stream + n as u64 - 1,
    ]
    .into_iter()
    .max()
    .unwrap()
}

/// Device == native == packed over every precision and both MAC
/// variants on a tail-word shape (k=65 needs two plane words per
/// vector, the second holding a single valid bit).
#[test]
fn device_matches_native_and_packed_across_bits_and_variants() {
    let (m, k, n) = (3usize, 65usize, 5usize);
    for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
        let sa = SaConfig::new(4, 16, variant);
        for bits in 1..=16u32 {
            let (a, b) = rand_operands(m, k, n, bits, 0xd00d + bits as u64);
            let native = matmul_native(&a, &b, m, k, n, bits).unwrap();
            let packed = matmul_packed(&a, &b, m, k, n, bits).unwrap();
            let (dev, stats) = device_matmul(sa, &a, &b, m, k, n, bits).unwrap();
            assert_eq!(dev, native, "{variant:?} @{bits}b: device vs native");
            assert_eq!(dev, packed, "{variant:?} @{bits}b: device vs packed");
            assert!(stats.tiles >= 1 && stats.instrs == stats.tiles * 3 + 1);
        }
    }
}

/// Sign-plane saturation: operands pinned at the two's-complement
/// extremes (including the asymmetric `min_value`, whose bit pattern
/// saturates the sign plane) over skewed tail-word shapes.
#[test]
fn device_handles_sign_saturation_and_tail_words() {
    for (m, k, n) in [(2usize, 127usize, 3usize), (5, 64, 2), (1, 1, 1), (4, 128, 16)] {
        for (variant, bits) in [
            (MacVariant::Booth, 4u32),
            (MacVariant::Booth, 16),
            (MacVariant::Sbmwc, 7),
            (MacVariant::Sbmwc, 16),
        ] {
            let sa = SaConfig::new(4, 16, variant);
            let (lo, hi) = (min_value(bits), max_value(bits));
            let a: Vec<i32> = (0..m * k).map(|i| if i % 2 == 0 { lo } else { hi }).collect();
            let b: Vec<i32> = (0..k * n).map(|i| if i % 3 == 0 { hi } else { lo }).collect();
            let native = matmul_native(&a, &b, m, k, n, bits).unwrap();
            let packed = matmul_packed(&a, &b, m, k, n, bits).unwrap();
            let (dev, _) = device_matmul(sa, &a, &b, m, k, n, bits).unwrap();
            assert_eq!(dev, native, "{m}x{k}x{n} {variant:?} @{bits}b vs native");
            assert_eq!(dev, packed, "{m}x{k}x{n} {variant:?} @{bits}b vs packed");
        }
    }
}

/// The streamed transport must not change measured tile timing: the
/// simulator's compute cycles equal the pre-refactor closed form, and
/// readout is always the full `rows×cols` snake drain.
#[test]
fn exec_cycles_match_the_pre_refactor_closed_form() {
    for (m, k, n, bits) in [
        (4usize, 32usize, 16usize, 8u32), // full tile
        (3, 65, 5, 7),                    // partial tile, tail word
        (1, 1, 1, 1),                     // degenerate
        (2, 300, 16, 16),                 // deep k, full cols
        (4, 10, 3, 2),                    // narrow precision
    ] {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (a, b) = rand_operands(m, k, n, bits, 0xf0f0 + k as u64);
        let mut arr = SystolicArray::new(sa);
        let out = arr.matmul(&a, &b, m, k, n, bits).unwrap();
        assert_eq!(
            out.stats.compute_cycles,
            pre_refactor_exec_cycles(&sa, m, n, k, bits),
            "{m}x{k}x{n} @{bits}b"
        );
        assert_eq!(out.stats.readout_cycles, (sa.rows * sa.cols) as u64);
    }
}

/// Whole-layer regression: the driver's hardware cycles (execute +
/// writeback) equal the per-job closed form summed over the tile plan
/// — streaming the fetches added *nothing* to the old totals — and the
/// pipelined schedule never exceeds the serial one.
#[test]
fn streamed_fetch_never_exceeds_old_totals() {
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    for (m, k, n, bits) in [(10usize, 130usize, 40usize, 6u32), (4, 64, 16, 8), (9, 65, 17, 3)] {
        let (a, b) = rand_operands(m, k, n, bits, 0xace + m as u64);
        let (_, d) = device_matmul(sa, &a, &b, m, k, n, bits).unwrap();
        let plan = tile_matmul(m, k, n, &sa);
        let expected: u64 = plan
            .jobs
            .iter()
            .map(|j| pre_refactor_exec_cycles(&sa, j.m, j.n, j.k, bits) + (sa.rows * sa.cols) as u64)
            .sum();
        assert_eq!(d.hw_cycles(), expected, "{m}x{k}x{n} @{bits}b hw cycles drifted");
        assert_eq!(d.tiles, plan.jobs.len() as u64);
        assert!(d.pipelined_cycles() <= d.serial_cycles());
        assert_eq!(d.fetch_cycles, d.overlap_cycles + d.stall_cycles);
        if plan.jobs.len() > 1 {
            assert!(d.overlap_cycles > 0, "{m}x{k}x{n}: multi-tile layer must overlap");
        } else {
            assert_eq!(d.overlap_cycles, 0, "single tile has nothing to overlap under");
        }
    }
}

/// Every zoo model serves bit-identically on the device backend — the
/// ISSUE acceptance gate. Native is the reference; packed rides along
/// to pin all three execution paths to the same integers.
#[test]
fn zoo_models_serve_bit_identical_on_the_device_backend() {
    for name in ["mlp", "mlp-headroom", "cnn", "attn"] {
        let model = Arc::new(zoo_model(name, 7).unwrap());
        let ins = shaped_inputs(&model, 4, 0xbeef);
        let cfg = |backend| {
            let mut c = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), backend);
            c.workers = 1;
            c
        };
        let (native, _, _) = serve_all(model.clone(), cfg(Backend::Native), ins.clone()).unwrap();
        let (packed, _, _) = serve_all(model.clone(), cfg(Backend::Packed), ins.clone()).unwrap();
        let (device, _, metrics) = serve_all(model, cfg(Backend::Simulate), ins).unwrap();
        for ((nr, pr), dr) in native.iter().zip(&packed).zip(&device) {
            assert_eq!(dr.output, nr.output, "{name} id {}: device vs native", nr.id);
            assert_eq!(dr.output, pr.output, "{name} id {}: device vs packed", nr.id);
        }
        if name == "mlp" {
            assert!(metrics.device.tiles > 0, "simulate backend must have streamed tiles");
            assert!(metrics.device.dma_words > 0);
            assert_eq!(
                metrics.device.fetch_cycles,
                metrics.device.overlap_cycles + metrics.device.stall_cycles
            );
        }
    }
}
