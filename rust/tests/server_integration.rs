//! Server-level integration: batching, multi-worker serving, backend
//! determinism, backpressure observability, and failure injection
//! (malformed requests must not take the server down).

use bitsmm::coordinator::{
    serve_all, shaped_inputs, Backend, BatcherConfig, FaultPlan, FaultState, InferenceServer,
    Request, ServeError, ServerConfig,
};
use bitsmm::nn::model::{mlp_headroom_zoo, mlp_zoo, zoo_model};
use bitsmm::nn::Layer;
use bitsmm::plan::{Planner, PlannerMode};
use bitsmm::prng::Pcg32;
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;
use std::sync::Arc;

fn inputs(n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| (0..64).map(|_| rng.range_i32(-128, 127)).collect())
        .collect()
}

fn base_cfg(workers: usize) -> ServerConfig {
    let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
    cfg.workers = workers;
    cfg.batcher = BatcherConfig {
        max_batch: 8,
        linger: std::time::Duration::from_millis(1),
        ..BatcherConfig::default()
    };
    cfg
}

#[test]
fn four_workers_serve_disjoint_requests() {
    let model = Arc::new(mlp_zoo(9));
    let (resp, report, metrics) = serve_all(model, base_cfg(4), inputs(97, 1)).unwrap();
    assert_eq!(resp.len(), 97);
    assert_eq!(metrics.requests, 97);
    // every id exactly once
    for (i, r) in resp.iter().enumerate() {
        assert_eq!(r.id, i as u64);
    }
    assert!(report.matmuls >= 3); // at least one batch of 3 layers
}

#[test]
fn worker_count_does_not_change_results() {
    let model = Arc::new(mlp_zoo(9));
    let ins = inputs(24, 2);
    let (r1, _, _) = serve_all(model.clone(), base_cfg(1), ins.clone()).unwrap();
    let (r4, _, _) = serve_all(model, base_cfg(4), ins).unwrap();
    for (a, b) in r1.iter().zip(&r4) {
        assert_eq!(a.output, b.output);
    }
}

#[test]
fn malformed_request_gets_error_response_not_silence() {
    let model = Arc::new(mlp_zoo(9));
    let server = InferenceServer::start(model, base_cfg(1)).unwrap();
    // out-of-range activation (300 exceeds 8-bit): the submitter gets
    // an error response carrying the cause, not an opaque RecvError
    let bad_rx = server.submit(Request::new(0, vec![300; 64]));
    let bad = bad_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    let err = bad.output.unwrap_err().to_string();
    assert!(err.contains("8-bit"), "error must name the cause: {err}");
    // a wrong-shape payload also surfaces its cause
    let err = server
        .submit(Request::new(1, vec![1; 32]))
        .recv_timeout(std::time::Duration::from_secs(5))
        .unwrap()
        .output
        .unwrap_err()
        .to_string();
    assert!(err.contains("shape"), "error must name the cause: {err}");
    // malformed batch-mates never take a valid request down
    let good_rx = server.submit(Request::new(2, vec![1; 64]));
    let good = good_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert_eq!(good.id, 2);
    assert!(good.output.is_ok());
    let (_, metrics) = server.shutdown();
    assert_eq!(metrics.requests, 1);
    assert_eq!(metrics.errors, 2);
}

#[test]
fn queue_depth_reflects_backlog() {
    let model = Arc::new(mlp_zoo(9));
    // zero workers is rejected; use a server whose single worker we
    // stall by submitting a large burst and checking depth observably
    let server = InferenceServer::start(model, base_cfg(1)).unwrap();
    let mut rxs = Vec::new();
    for (i, input) in inputs(64, 3).into_iter().enumerate() {
        rxs.push(server.submit(Request::new(i as u64, input)));
    }
    // depth is a point-in-time observation; it must never exceed the
    // submitted count and must drain to zero by shutdown
    assert!(server.queue_depth() <= 64);
    for rx in rxs {
        rx.recv().unwrap();
    }
    assert_eq!(server.queue_depth(), 0);
    server.shutdown();
}

/// The packed backend serves bit-identical results to the native one
/// and packs each layer's weights exactly once per (layer, precision)
/// even with multiple workers racing over many batches — the serving
/// invariant the per-layer `PackedCache` exists for.
#[test]
fn packed_backend_identical_results_and_packs_weights_once() {
    let model = Arc::new(mlp_zoo(9));
    let ins = inputs(48, 11);
    let (want, _, _) = serve_all(model.clone(), base_cfg(2), ins.clone()).unwrap();

    let mut cfg = base_cfg(4);
    cfg.backend = Backend::Packed;
    let (got, report, metrics) = serve_all(model.clone(), cfg, ins).unwrap();
    assert_eq!(metrics.requests, 48);
    assert!(report.packed_execs > 0, "packed engine must have executed");
    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a.output, b.output, "packed vs native diverged at id {}", a.id);
    }
    // 4 workers × many batches, but each (layer, precision) packed once
    for (i, layer) in model.layers.iter().enumerate() {
        if let bitsmm::nn::Layer::Linear(l) = layer {
            assert_eq!(l.packed.packs(), 1, "layer {i} packed more than once");
        }
    }
}

/// Serving the *same* model at two precisions under `Backend::Packed`
/// packs each weight matrix exactly once: the higher-precision serve
/// packs, the precision-lowered serve slices plane subsets out of the
/// cached packs (zero re-packs) — the cross-precision extension of the
/// packs-once invariant. Lowering the declared precision must not
/// change the served integers either (the matmuls are exact).
#[test]
fn two_precision_serving_packs_each_weight_once() {
    let lo = Arc::new(mlp_zoo(9)); // layer precisions 8 / 4 / 4
    // same layers (weights AND packed caches shared via clone), with
    // every layer's declared precision raised by 4 bits
    let mut hi = (*lo).clone();
    for layer in &mut hi.layers {
        if let bitsmm::nn::Layer::Linear(l) = layer {
            l.bits += 4; // 12 / 8 / 8
        }
    }
    let hi = Arc::new(hi);
    let ins = inputs(24, 17);

    let mut cfg = base_cfg(4);
    cfg.backend = Backend::Packed;
    let (resp_hi, _, _) = serve_all(hi.clone(), cfg.clone(), ins.clone()).unwrap();
    for (i, layer) in hi.layers.iter().enumerate() {
        if let bitsmm::nn::Layer::Linear(l) = layer {
            assert_eq!(l.packed.packs(), 1, "layer {i}: first serve packs once");
            assert_eq!(l.packed.plane_reuses(), 0, "layer {i}: nothing to reuse yet");
        }
    }

    // precision-lowered serve: zero additional packs, one slice/layer
    let (resp_lo, report, _) = serve_all(lo.clone(), cfg, ins).unwrap();
    assert!(report.packed_execs > 0, "packed engine served the low run");
    for (i, layer) in lo.layers.iter().enumerate() {
        if let bitsmm::nn::Layer::Linear(l) = layer {
            assert_eq!(
                l.packed.packs(),
                1,
                "layer {i}: lowering precision must not re-pack"
            );
            assert_eq!(
                l.packed.plane_reuses(),
                1,
                "layer {i}: the lower precision is a plane-subset slice"
            );
        }
    }
    // exact integer matmuls: the declared width does not change results
    for (a, b) in resp_hi.iter().zip(&resp_lo) {
        assert_eq!(a.output, b.output, "precision switch changed results at id {}", a.id);
    }
}

/// The work-stealing 2-D tile scheduler behind `Backend::Packed` must
/// not change served integers for any tile granularity — including
/// degenerate 1×1 tiles that maximise steal traffic — and its
/// steal/imbalance telemetry must surface through the server metrics.
#[test]
fn tile_granularity_never_changes_served_results() {
    let model = Arc::new(mlp_zoo(9));
    let ins = inputs(24, 21);
    let (want, _, _) = serve_all(model.clone(), base_cfg(2), ins.clone()).unwrap();
    for (rows, cols) in [(0usize, 0usize), (1, 1), (0, 3), (4, 0)] {
        let mut cfg = base_cfg(2);
        cfg.backend = Backend::Packed;
        cfg.packed_threads = 3;
        cfg.packed_tile_rows = rows;
        cfg.packed_tile_cols = cols;
        let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.output, b.output, "tiles {rows}x{cols} diverged at id {}", a.id);
        }
        assert!(report.steal.tiles >= 1, "tiles {rows}x{cols}: no pooled run recorded");
        assert_eq!(metrics.steal, report.steal, "metrics mirror the report");
        assert!(report.steal.max_worker_tiles >= report.steal.min_worker_tiles);
        assert!(metrics.steal_rate() >= 0.0 && metrics.steal_rate() <= 1.0);
    }
}

/// Warm-start serving: a packed server pre-packs **every** weight's
/// bit planes (and conv im2col transposes) during `start`, before any
/// request can be submitted — the first request pays zero pack
/// latency, and serving afterwards still packs nothing new.
#[test]
fn warm_start_packs_every_weight_before_first_submit() {
    for name in ["mlp", "cnn", "attn"] {
        let model = Arc::new(zoo_model(name, 13).unwrap());
        let mut cfg = base_cfg(2);
        cfg.backend = Backend::Packed;
        let server = InferenceServer::start(model.clone(), cfg).unwrap();
        // no request has been submitted yet: everything is packed
        for (i, layer) in model.layers.iter().enumerate() {
            match layer {
                Layer::Linear(l) => {
                    assert_eq!(l.packed.packs(), 1, "{name} layer {i}: packed before submit")
                }
                Layer::Conv2d(l) => {
                    assert_eq!(l.packed.packs(), 1, "{name} layer {i}: packed before submit");
                    assert!(l.wt.is_built(), "{name} layer {i}: transpose before submit");
                }
                Layer::Attention(l) => {
                    assert_eq!(l.packed.packs(), 4, "{name} layer {i}: q/k/v/o before submit")
                }
                Layer::Flatten => {}
            }
        }
        // serving afterwards reuses the warm packs — zero new packs
        let inputs = shaped_inputs(&model, 6, 0x77);
        let rxs: Vec<_> = inputs
            .into_iter()
            .enumerate()
            .map(|(i, input)| server.submit(Request::new(i as u64, input)))
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().output.is_ok(), "{name}");
        }
        let (report, _) = server.shutdown();
        assert!(report.packed_execs > 0, "{name}: served on the packed engine");
        for layer in model.layers.iter() {
            match layer {
                Layer::Linear(l) => assert_eq!(l.packed.packs(), 1, "{name}"),
                Layer::Conv2d(l) => assert_eq!(l.packed.packs(), 1, "{name}"),
                Layer::Attention(l) => assert_eq!(l.packed.packs(), 4, "{name}"),
                Layer::Flatten => {}
            }
        }
    }
}

/// The execution planner serves bit-identical results in every mode,
/// and a `tune`-written plan file round-trips into a serving run: the
/// server loads it, resolves the census from exact hits, and reports
/// the plan telemetry through the metrics.
#[test]
fn planner_serving_is_bit_identical_and_roundtrips_plan_files() {
    let model = Arc::new(mlp_zoo(9));
    let ins = inputs(24, 19);
    let (want, _, _) = serve_all(model.clone(), base_cfg(2), ins.clone()).unwrap();

    // online serve: calibrates its census at warm start
    let mut cfg = base_cfg(2);
    cfg.backend = Backend::Packed;
    cfg.packed_threads = 2;
    let online = Arc::new(Planner::new(PlannerMode::Online, 3));
    cfg.planner = Some(online.clone());
    let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a.output, b.output, "online planner diverged at id {}", a.id);
    }
    assert!(report.plan.lookups() > 0);
    assert!(report.plan.hits > 0, "warm-start calibration fills the cache");
    assert_eq!(metrics.plan, report.plan);
    assert!(online.stats().calibrations > 0);
    assert!(online.len() > 0);

    // persist the calibrated cache, load it into a *static* planner,
    // serve again: identical results, and the loaded entries resolve
    let path = std::env::temp_dir().join("bitsmm_serve_plans.json");
    let written = online.save_file(&path).unwrap();
    assert!(written > 0);
    let mut cfg = base_cfg(2);
    cfg.backend = Backend::Packed;
    cfg.packed_threads = 2;
    let loaded = Arc::new(Planner::new(PlannerMode::Static, 3));
    assert_eq!(loaded.load_file(&path).unwrap(), written);
    cfg.planner = Some(loaded.clone());
    let (got, report, _) = serve_all(model, cfg, ins).unwrap();
    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a.output, b.output, "loaded-plan serving diverged at id {}", a.id);
    }
    assert!(report.plan.hits > 0, "loaded plans hit on the request path");
    assert_eq!(
        loaded.stats().calibrations, 0,
        "static mode never benchmarks on the request path"
    );
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn zero_workers_rejected() {
    let model = Arc::new(mlp_zoo(9));
    let mut cfg = base_cfg(1);
    cfg.workers = 0;
    assert!(InferenceServer::start(model, cfg).is_err());
}

#[test]
fn latency_metrics_populated() {
    let model = Arc::new(mlp_zoo(9));
    let (_, _, metrics) = serve_all(model, base_cfg(2), inputs(32, 4)).unwrap();
    assert_eq!(metrics.latency.count(), 32);
    assert!(metrics.latency.percentile_us(50.0) <= metrics.latency.percentile_us(99.0));
    assert!(metrics.throughput_rps() > 0.0);
    assert!(metrics.hw_cycles > 0);
}

/// The whole zoo serves end-to-end, and serving is **batch-invariant**:
/// a request's output is bit-identical whether it is served alone
/// (max_batch = 1) or fused into a batch. For attention this is the
/// per-item guarantee that the data-dependent `ctx_scale`
/// requantization never mixes requests.
#[test]
fn zoo_models_are_batch_invariant() {
    for name in ["mlp", "cnn", "attn"] {
        let model = Arc::new(zoo_model(name, 5).unwrap());
        let ins = shaped_inputs(&model, 6, 31);
        let mut solo_cfg = base_cfg(1);
        solo_cfg.batcher = BatcherConfig {
            max_batch: 1,
            linger: std::time::Duration::from_millis(1),
            ..BatcherConfig::default()
        };
        let (solo, _, _) = serve_all(model.clone(), solo_cfg, ins.clone()).unwrap();
        let mut fused_cfg = base_cfg(1);
        fused_cfg.batcher = BatcherConfig {
            max_batch: 6,
            linger: std::time::Duration::from_millis(20),
            ..BatcherConfig::default()
        };
        let (fused, _, metrics) = serve_all(model, fused_cfg, ins).unwrap();
        assert_eq!(metrics.requests, 6, "{name}");
        assert_eq!(metrics.errors, 0, "{name}");
        for (a, b) in solo.iter().zip(&fused) {
            assert!(a.output.is_ok(), "{name}: solo request {} failed", a.id);
            assert_eq!(a.output, b.output, "{name}: solo vs batched diverged at id {}", a.id);
        }
    }
}

/// Cross-backend determinism through the *serving* path for every zoo
/// model: Native == Simulate == Packed, bit for bit.
#[test]
fn zoo_models_deterministic_across_backends() {
    for name in ["mlp", "cnn", "attn"] {
        let model = Arc::new(zoo_model(name, 5).unwrap());
        let ins = shaped_inputs(&model, 4, 47);
        let (native, _, _) = serve_all(model.clone(), base_cfg(2), ins.clone()).unwrap();
        let mut sim_cfg = base_cfg(1);
        sim_cfg.backend = Backend::Simulate;
        let (sim, _, _) = serve_all(model.clone(), sim_cfg, ins.clone()).unwrap();
        let mut packed_cfg = base_cfg(2);
        packed_cfg.backend = Backend::Packed;
        let (packed, report, _) = serve_all(model, packed_cfg, ins).unwrap();
        assert!(report.packed_execs > 0, "{name}: packed engine must have executed");
        for ((a, s), p) in native.iter().zip(&sim).zip(&packed) {
            assert!(a.output.is_ok(), "{name}: request {} failed", a.id);
            assert_eq!(a.output, s.output, "{name}: native vs simulate diverged at id {}", a.id);
            assert_eq!(a.output, p.output, "{name}: native vs packed diverged at id {}", a.id);
        }
    }
}

/// Packed serving packs each conv kernel (slot 0) and each attention
/// projection (slots 0..=3) exactly once per precision, even with four
/// workers racing over many per-item batches.
#[test]
fn conv_and_attention_weights_pack_once_under_multiworker_serving() {
    let mut cfg = base_cfg(4);
    cfg.backend = Backend::Packed;

    let cnn = Arc::new(zoo_model("cnn", 2).unwrap());
    let (resp, report, _) = serve_all(cnn.clone(), cfg.clone(), shaped_inputs(&cnn, 16, 7)).unwrap();
    assert!(resp.iter().all(|r| r.output.is_ok()));
    assert!(report.packed_execs > 0, "cnn must serve on the packed engine");
    for (i, layer) in cnn.layers.iter().enumerate() {
        match layer {
            Layer::Conv2d(l) => {
                assert_eq!(l.packed.packs(), 1, "conv layer {i} packed more than once");
                assert!(l.wt.is_built(), "conv layer {i} never cached its transpose");
            }
            Layer::Linear(l) => assert_eq!(l.packed.packs(), 1, "linear layer {i}"),
            Layer::Attention(_) | Layer::Flatten => {}
        }
    }

    let attn = Arc::new(zoo_model("attn", 3).unwrap());
    let (resp, report, _) = serve_all(attn.clone(), cfg, shaped_inputs(&attn, 16, 8)).unwrap();
    assert!(resp.iter().all(|r| r.output.is_ok()));
    assert!(report.packed_execs > 0, "attn must serve on the packed engine");
    let Layer::Attention(l) = &attn.layers[0] else {
        panic!("attention zoo starts with its attention block");
    };
    // four projection slots (q/k/v/o), one pack each, zero re-packs
    assert_eq!(l.packed.packs(), 4, "q/k/v/o must pack exactly once each");
    assert_eq!(l.packed.plane_reuses(), 0);
}

/// Chaos drill through the public API: a deterministic fault plan
/// (worker panic, dropped pool job, SEU bit-flip) against the packed
/// backend with ABFT on. The server must survive, every submitter must
/// get a terminal typed answer, and every request that still produced
/// an output must be bit-identical to a fault-free baseline — the
/// tentpole resilience contract end to end.
#[test]
fn injected_faults_are_survived_masked_and_bit_identical() {
    let model = Arc::new(mlp_headroom_zoo(3));
    let ins = shaped_inputs(&model, 24, 42);
    let cfg = |faulty: bool| {
        let mut cfg = base_cfg(1); // one worker: deterministic batch ids
        cfg.backend = Backend::Packed;
        cfg.packed_threads = 2;
        cfg.batcher.max_batch = 4;
        if faulty {
            cfg.abft = true;
            cfg.faults = Some(Arc::new(FaultState::new(
                FaultPlan::parse("panic@1,drop@2,seu@3,seed=42").unwrap(),
            )));
        }
        cfg
    };
    let (baseline, _, clean) = serve_all(model.clone(), cfg(false), ins.clone()).unwrap();
    assert_eq!(clean.panics, 0);
    assert!(baseline.iter().all(|r| r.output.is_ok()));

    let (responses, _, metrics) = serve_all(model, cfg(true), ins).unwrap();
    assert_eq!(responses.len(), 24, "every submitter got a terminal answer");
    let mut ok = 0usize;
    let mut faulted = 0usize;
    for (want, got) in baseline.iter().zip(&responses) {
        assert_eq!(want.id, got.id);
        match &got.output {
            Ok(out) => {
                assert_eq!(
                    out,
                    want.output.as_ref().unwrap(),
                    "request {} diverged under fault injection",
                    got.id
                );
                ok += 1;
            }
            Err(ServeError::WorkerFault(_)) => faulted += 1,
            Err(e) => panic!("unexpected terminal error: {e}"),
        }
    }
    assert_eq!(ok + faulted, 24);
    assert!(metrics.panics >= 1, "the planned panic fired under supervision");
    assert!(faulted >= 1, "the panicked batch answered its own requests");
    assert!(metrics.faults.injected >= 2, "drop + SEU were injected");
    assert_eq!(metrics.faults.unmasked, 0, "ABFT + work stealing masked all");
}

/// Admission control and age shedding through the public API: a worker
/// stalled by an injected delay, a bounded queue, and a tiny age
/// budget — the flood gets typed `Rejected` answers, the stale queue
/// gets `Overloaded`, and nothing hangs or is silently dropped.
#[test]
fn bounded_queue_rejects_and_age_budget_sheds_under_stall() {
    let model = Arc::new(mlp_headroom_zoo(3));
    let mut cfg = base_cfg(1);
    cfg.batcher = BatcherConfig {
        max_batch: 4,
        linger: std::time::Duration::from_millis(1),
        max_queue: 4,
        shed_after: Some(std::time::Duration::from_millis(10)),
    };
    cfg.faults = Some(Arc::new(FaultState::new(
        FaultPlan::parse("delay@0:300ms").unwrap(),
    )));
    let server = InferenceServer::start(model.clone(), cfg).unwrap();
    let mut ins = shaped_inputs(&model, 24, 42).into_iter().enumerate();
    let mut rxs = Vec::new();
    // wave 1 fills the first batch; wait for the worker to dequeue it
    // and enter the injected stall
    for (i, input) in ins.by_ref().take(4) {
        rxs.push(server.submit(Request::new(i as u64, input)));
    }
    std::thread::sleep(std::time::Duration::from_millis(50));
    // wave 2 floods the stalled server
    for (i, input) in ins {
        rxs.push(server.submit(Request::new(i as u64, input)));
    }
    let (mut served, mut rejected, mut shed) = (0usize, 0usize, 0usize);
    for rx in rxs {
        match rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("every submitter gets a terminal answer")
            .output
        {
            Ok(_) => served += 1,
            Err(ServeError::Rejected { depth }) => {
                assert!(depth >= 4);
                rejected += 1;
            }
            Err(ServeError::Overloaded { waited }) => {
                assert!(waited >= std::time::Duration::from_millis(10));
                shed += 1;
            }
            Err(e) => panic!("unexpected terminal error: {e}"),
        }
    }
    let (_, metrics) = server.shutdown();
    assert_eq!(served + rejected + shed, 24);
    assert!(rejected >= 1, "the bounded queue refused part of the flood");
    assert!(shed >= 1, "the age budget shed the stalled queue");
    assert_eq!(metrics.rejected as usize, rejected);
    assert_eq!(metrics.sheds as usize, shed);
}
