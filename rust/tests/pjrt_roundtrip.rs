//! Integration of the AOT path: HLO artifacts produced by the Python
//! L1/L2 layers, loaded and executed from Rust through PJRT, must be
//! bit-identical to the native Booth-plane path and the cycle-accurate
//! hardware simulator.
//!
//! Requires `make artifacts`; each test skips (with a notice) when the
//! artifact directory is absent so `cargo test` stays green on a fresh
//! checkout.

use bitsmm::coordinator::{Backend, Scheduler};
use bitsmm::prng::Pcg32;
use bitsmm::runtime::{EngineHandle, IntMat};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::driver::ref_matmul_i64;
use bitsmm::sim::mac_common::MacVariant;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = bitsmm::runtime::default_artifact_dir();
    let dir = if dir.is_relative() {
        // cargo test runs from the workspace root
        std::env::current_dir().ok()?.join(dir)
    } else {
        dir
    };
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] no artifacts at {} — run `make artifacts`", dir.display());
        None
    }
}

fn rand_ops(seed: u64, m: usize, k: usize, n: usize, bits: u32) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Pcg32::new(seed);
    let lo = bitsmm::bits::twos::min_value(bits);
    let hi = bitsmm::bits::twos::max_value(bits);
    (
        (0..m * k).map(|_| rng.range_i32(lo, hi)).collect(),
        (0..k * n).map(|_| rng.range_i32(lo, hi)).collect(),
    )
}

#[test]
fn pjrt_matmul_matches_native_all_artifact_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, _join) = EngineHandle::spawn(&dir).expect("engine");
    // exercise every registered f32 matmul artifact
    let shapes = [
        (8usize, 64usize, 64usize),
        (8, 64, 32),
        (8, 32, 10),
        (32, 64, 64),
        (32, 64, 32),
        (32, 32, 10),
        (64, 128, 128),
    ];
    for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
        for bits in [2u32, 4, 8] {
            for &(m, k, n) in &shapes {
                let (a, b) = rand_ops(m as u64 * 31 + bits as u64, m, k, n, bits);
                let got = engine
                    .execute_matmul(
                        IntMat::new(a.clone(), m, k).unwrap(),
                        IntMat::new(b.clone(), k, n).unwrap(),
                        bits,
                        variant,
                    )
                    .expect("execute")
                    .unwrap_or_else(|| panic!("artifact missing for {m}x{k}x{n} b{bits} {variant:?}"));
                let want = ref_matmul_i64(&a, &b, m, k, n);
                let got_i: Vec<i64> = got.iter().map(|&v| v.round() as i64).collect();
                assert_eq!(got_i, want, "{variant:?} {m}x{k}x{n} @{bits}b");
            }
        }
    }
    engine.shutdown();
}

#[test]
fn pjrt_exact_f64_artifact_at_16_bits() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, _join) = EngineHandle::spawn(&dir).expect("engine");
    let (m, k, n, bits) = (8usize, 64usize, 64usize, 16u32);
    let (a, b) = rand_ops(0xe8ac, m, k, n, bits);
    let got = engine
        .execute(
            "mm_booth_b16_8x64x64_exact",
            vec![
                IntMat::new(a.clone(), m, k).unwrap(),
                IntMat::new(b.clone(), k, n).unwrap(),
            ],
        )
        .expect("execute exact");
    let want = ref_matmul_i64(&a, &b, m, k, n);
    let got_i: Vec<i64> = got.iter().map(|&v| v.round() as i64).collect();
    assert_eq!(got_i, want, "f64 artifact must be exact at 16-bit operands");
    engine.shutdown();
}

#[test]
fn pjrt_backend_cosimulates_with_hardware_sim() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, _join) = EngineHandle::spawn(&dir).expect("engine");
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let (m, k, n, bits) = (8usize, 64usize, 32usize, 8u32);
    let (a, b) = rand_ops(0xc051, m, k, n, bits);

    let mut pjrt = Scheduler::new(sa, Backend::Pjrt(engine.clone()));
    let mut sim = Scheduler::new(sa, Backend::Simulate);
    let y1 = pjrt.matmul(&a, &b, m, k, n, bits).unwrap();
    let y2 = sim.matmul(&a, &b, m, k, n, bits).unwrap();
    assert_eq!(y1, y2, "PJRT and cycle-accurate sim must be bit-identical");
    assert_eq!(pjrt.report.pjrt_hits, 1);
    assert_eq!(pjrt.report.native_fallbacks, 0);
    engine.shutdown();
}

#[test]
fn pjrt_unregistered_shape_falls_back_natively() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, _join) = EngineHandle::spawn(&dir).expect("engine");
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let (m, k, n, bits) = (3usize, 11usize, 5usize, 7u32); // no artifact
    let (a, b) = rand_ops(7, m, k, n, bits);
    let mut sched = Scheduler::new(sa, Backend::Pjrt(engine.clone()));
    let y = sched.matmul(&a, &b, m, k, n, bits).unwrap();
    assert_eq!(y, ref_matmul_i64(&a, &b, m, k, n));
    assert_eq!(sched.report.pjrt_hits, 0);
    assert_eq!(sched.report.native_fallbacks, 1);
    engine.shutdown();
}

#[test]
fn pjrt_mlp_artifact_runs() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, _join) = EngineHandle::spawn(&dir).expect("engine");
    // the mlp_8 artifact embeds its parameter shapes: x[8,64] + 3 W + 3 b
    let mut rng = Pcg32::new(0x31);
    let x = IntMat::new((0..8 * 64).map(|_| rng.range_i32(-128, 127)).collect(), 8, 64).unwrap();
    let dims = [(64usize, 64usize), (64, 32), (32, 10)];
    let mut inputs = vec![x];
    for &(i, o) in &dims {
        inputs.push(IntMat::new((0..i * o).map(|_| rng.range_i32(-63, 63)).collect(), i, o).unwrap());
    }
    for &(_, o) in &dims {
        inputs.push(IntMat::vec((0..o).map(|_| rng.range_i32(-128, 127)).collect()));
    }
    let out = engine.execute("mlp_8", inputs).expect("mlp artifact");
    assert_eq!(out.len(), 8 * 10);
    assert!(out.iter().all(|v| v.is_finite()));
    engine.shutdown();
}
