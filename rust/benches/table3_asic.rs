//! Bench T3: regenerate paper Table III (asap7 + nangate45 physical
//! implementation) from the calibrated PDK models and assert the
//! paper's qualitative findings.

use bitsmm::arch::asic::AsicModel;
use bitsmm::arch::pdk::PdkKind;
use bitsmm::report::f;
use bitsmm::sim::mac_common::MacVariant;

fn main() {
    bitsmm::bench_harness::header("table3_asic", "paper Table III: ASIC synthesis results");
    print!("{}", bitsmm::report::paper::render_table3());

    for kind in [PdkKind::Asap7, PdkKind::Nangate45] {
        let rows = AsicModel::new(kind).table3_rows();
        // area and power scale ~proportionally with SA size
        let booth: Vec<_> = rows
            .iter()
            .filter(|r| r.config.variant == MacVariant::Booth)
            .collect();
        for w in booth.windows(2) {
            let mac_ratio = w[1].config.macs() as f64 / w[0].config.macs() as f64;
            let area_ratio = w[1].area_mm2 / w[0].area_mm2;
            let pow_ratio = w[1].power_w / w[0].power_w;
            assert!(
                (area_ratio / mac_ratio - 1.0).abs() < 0.1,
                "{kind:?} area not proportional: {area_ratio} vs {mac_ratio}"
            );
            assert!((pow_ratio / mac_ratio - 1.0).abs() < 0.1);
        }
        // consistent GOPS/W across sizes
        let gpw: Vec<f64> = booth.iter().map(|r| r.gops_per_w).collect();
        let mean = gpw.iter().sum::<f64>() / gpw.len() as f64;
        assert!(gpw.iter().all(|g| (g - mean).abs() / mean < 0.06));
        println!(
            "{}: GOPS/W consistent across sizes (mean {})",
            kind.name(),
            f(mean)
        );
    }

    // headline: asap7 peak numbers
    let a7 = AsicModel::new(PdkKind::Asap7).table3_rows();
    let peak = a7.iter().map(|r| r.peak_gops_at_fmax).fold(0.0, f64::max);
    let per_mm2 = a7.iter().map(|r| r.gops_per_mm2).fold(0.0, f64::max);
    let per_w = a7.iter().map(|r| r.gops_per_w).fold(0.0, f64::max);
    println!(
        "asap7 headline: up to {} GOPS, {} GOPS/mm2, {} GOPS/W (paper: 73.22 / 552 / 40.8)",
        f(peak),
        f(per_mm2),
        f(per_w)
    );
    assert!((peak - 73.22).abs() / 73.22 < 0.05);
    assert!((per_mm2 - 552.0).abs() / 552.0 < 0.08);
    assert!((per_w - 40.8).abs() / 40.8 < 0.08);
    println!("table3 bench OK");
}
