//! Bench PERF: host-side hot-path microbenchmarks feeding the §Perf
//! iteration log — simulator inner loop, native matmul, the per-plane
//! and word-packed plane realisations (the headline comparison for the
//! packed engine), tiler, and (when artifacts are built) the PJRT
//! request path. Every result is also written to
//! `BENCH_perf_hotpath.json` so the perf trajectory is machine-
//! trackable across PRs.

use bitsmm::bench_harness::{bench, BenchConfig, BenchResult};
use bitsmm::bits::packed::{matmul_packed_planes, PackedPlanes};
use bitsmm::bits::plane::PlaneKind;
use bitsmm::coordinator::{tile_matmul, Backend, Scheduler};
use bitsmm::nn::{matmul_native, matmul_packed, matmul_planes};
use bitsmm::prng::Pcg32;
use bitsmm::sim::array::{SaConfig, SystolicArray};
use bitsmm::sim::driver::mac_dot;
use bitsmm::sim::mac_common::MacVariant;

fn main() {
    bitsmm::bench_harness::header("perf_hotpath", "host hot paths (native vs planes vs packed)");
    let cfg = BenchConfig::default();
    let mut rng = Pcg32::new(0x9e4f);
    let mut log: Vec<BenchResult> = Vec::new();

    // ---- 1. single-MAC stepping ---------------------------------------
    let mc: Vec<i32> = (0..256).map(|_| rng.range_i32(-128, 127)).collect();
    let ml: Vec<i32> = (0..256).map(|_| rng.range_i32(-128, 127)).collect();
    let r = bench("mac_dot booth len=256 @8b", cfg, || {
        mac_dot(MacVariant::Booth, &mc, &ml, 8, 48)
    });
    println!("{}   ({} Mcycle/s simulated)", r.format(), fmt_rate(r.per_second(257.0 * 8.0) / 1e6));
    log.push(r);

    // ---- 2. full SA matmul (the simulator inner loop) -------------------
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let (m, k, n, bits) = (4usize, 64usize, 16usize, 8u32);
    let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(-128, 127)).collect();
    let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(-128, 127)).collect();
    let mut arr = SystolicArray::new(sa);
    let cycles = arr.matmul(&a, &b, m, k, n, bits).unwrap().stats.total_cycles();
    let r = bench("sa.matmul 4x64x16 @8b (16x4 array)", cfg, || {
        arr.matmul(&a, &b, m, k, n, bits).unwrap().result[0]
    });
    println!(
        "{}   ({} Msim-cycle/s, {} MACsteps/s)",
        r.format(),
        fmt_rate(r.per_second(cycles as f64) / 1e6),
        fmt_rate(r.per_second(cycles as f64 * 64.0) / 1e6)
    );
    log.push(r);

    // ---- 3. native vs per-plane vs packed, bit-width sweep --------------
    // The packed engine's plane-pair count grows with bits² while its
    // word count shrinks 64×, so the sweep shows where each
    // realisation wins (see DESIGN.md §Packed-Planes).
    let (m2, k2, n2) = (64usize, 128usize, 64usize);
    let macs2 = (m2 * k2 * n2) as f64;
    for bits in [1u32, 2, 4, 8, 16] {
        let lo = bitsmm::bits::twos::min_value(bits);
        let hi = bitsmm::bits::twos::max_value(bits);
        let a2: Vec<i32> = (0..m2 * k2).map(|_| rng.range_i32(lo, hi)).collect();
        let b2: Vec<i32> = (0..k2 * n2).map(|_| rng.range_i32(lo, hi)).collect();
        for (name, f) in [
            ("native", matmul_native as fn(&[i32], &[i32], usize, usize, usize, u32) -> bitsmm::Result<Vec<i64>>),
            ("planes", matmul_planes),
            ("packed", matmul_packed),
        ] {
            let r = bench(&format!("matmul_{name} 64x128x64 @{bits}b"), cfg, || {
                f(&a2, &b2, m2, k2, n2, bits).unwrap()[0]
            });
            println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs2) / 1e6));
            log.push(r);
        }
    }

    // ---- 4. the acceptance matrix: 256x256x256 @8b ----------------------
    // (bigger problem, fewer iterations; packed must beat planes here)
    let big = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        target_time: std::time::Duration::from_millis(400),
    };
    let (m3, k3, n3, bits3) = (256usize, 256usize, 256usize, 8u32);
    let macs3 = (m3 * k3 * n3) as f64;
    let a3: Vec<i32> = (0..m3 * k3).map(|_| rng.range_i32(-128, 127)).collect();
    let b3: Vec<i32> = (0..k3 * n3).map(|_| rng.range_i32(-128, 127)).collect();
    let mut planes_mean = 0f64;
    let mut packed_mean = 0f64;
    for (name, f) in [
        ("native", matmul_native as fn(&[i32], &[i32], usize, usize, usize, u32) -> bitsmm::Result<Vec<i64>>),
        ("planes", matmul_planes),
        ("packed", matmul_packed),
    ] {
        let r = bench(&format!("matmul_{name} 256x256x256 @{bits3}b"), big, || {
            f(&a3, &b3, m3, k3, n3, bits3).unwrap()[0]
        });
        println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs3) / 1e6));
        match name {
            "planes" => planes_mean = r.mean.as_secs_f64(),
            "packed" => packed_mean = r.mean.as_secs_f64(),
            _ => {}
        }
        log.push(r);
    }
    if packed_mean > 0.0 && planes_mean > 0.0 {
        println!(
            "packed vs per-plane speedup @8b 256^3: {:.2}x",
            planes_mean / packed_mean
        );
    }

    // ---- 5. packed kernel with pre-packed (cached) weights --------------
    // the serving steady state: only the streamed operand packs per call
    let pb = PackedPlanes::pack_cols(&b3, k3, n3, bits3, PlaneKind::Sbmwc).unwrap();
    let r = bench("matmul_packed 256x256x256 @8b cached-W", big, || {
        let pa = PackedPlanes::pack_rows(&a3, m3, k3, bits3, PlaneKind::Sbmwc).unwrap();
        matmul_packed_planes(&pa, &pb).unwrap()[0]
    });
    println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs3) / 1e6));
    log.push(r);

    // ---- 6. tiler ---------------------------------------------------------
    let r = bench("tile_matmul 512x512x512 on 16x4", cfg, || {
        tile_matmul(512, 512, 512, &sa).jobs.len()
    });
    println!("{}", r.format());
    log.push(r);

    // ---- 7. scheduler end-to-end (native + packed backends) -------------
    let (m4, k4, n4) = (32usize, 128usize, 64usize);
    let macs4 = (m4 * k4 * n4) as f64;
    let a4: Vec<i32> = (0..m4 * k4).map(|_| rng.range_i32(-128, 127)).collect();
    let b4: Vec<i32> = (0..k4 * n4).map(|_| rng.range_i32(-128, 127)).collect();
    for backend in [Backend::Native, Backend::Packed] {
        let name = backend.name();
        let mut sched = Scheduler::new(sa, backend);
        let r = bench(&format!("scheduler.matmul 32x128x64 @8b {name}"), cfg, || {
            sched.matmul(&a4, &b4, m4, k4, n4, 8).unwrap()[0]
        });
        println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs4) / 1e6));
        log.push(r);
    }

    // ---- 8. PJRT request path (if artifacts are built) ------------------
    let dir = bitsmm::runtime::default_artifact_dir();
    match bitsmm::runtime::EngineHandle::spawn(&dir) {
        Ok((engine, _join)) => {
            engine.warm_up().expect("warm up");
            let a5: Vec<i32> = (0..8 * 64).map(|_| rng.range_i32(-128, 127)).collect();
            let b5: Vec<i32> = (0..64 * 64).map(|_| rng.range_i32(-128, 127)).collect();
            let am = bitsmm::runtime::IntMat::new(a5, 8, 64).unwrap();
            let bm = bitsmm::runtime::IntMat::new(b5, 64, 64).unwrap();
            let r = bench("pjrt mm_booth_b8_8x64x64 round trip", cfg, || {
                engine
                    .execute_matmul(am.clone(), bm.clone(), 8, MacVariant::Booth)
                    .unwrap()
                    .unwrap()[0]
            });
            println!("{}   ({} req/s)", r.format(), fmt_rate(r.per_second(1.0)));
            log.push(r);
            engine.shutdown();
        }
        Err(e) => println!("pjrt path skipped: {e:#}"),
    }

    match bitsmm::bench_harness::write_json("perf_hotpath", &log) {
        Ok(path) => println!("\nwrote {path} ({} results)", log.len()),
        Err(e) => println!("\ncould not write bench json: {e}"),
    }
    println!("perf_hotpath bench OK");
}

fn fmt_rate(v: f64) -> String {
    bitsmm::report::f(v)
}
