//! Bench PERF: host-side hot-path microbenchmarks feeding the §Perf
//! iteration log in EXPERIMENTS.md — simulator inner loop, native
//! plane matmul, tiler, batcher, and (when artifacts exist) the PJRT
//! request path.

use bitsmm::bench_harness::{bench, BenchConfig};
use bitsmm::coordinator::{tile_matmul, Backend, Scheduler};
use bitsmm::nn::matmul_native;
use bitsmm::prng::Pcg32;
use bitsmm::sim::array::{SaConfig, SystolicArray};
use bitsmm::sim::driver::mac_dot;
use bitsmm::sim::mac_common::MacVariant;

fn main() {
    bitsmm::bench_harness::header("perf_hotpath", "host hot paths (see EXPERIMENTS.md §Perf)");
    let cfg = BenchConfig::default();
    let mut rng = Pcg32::new(0x9e4f);

    // ---- 1. single-MAC stepping ---------------------------------------
    let mc: Vec<i32> = (0..256).map(|_| rng.range_i32(-128, 127)).collect();
    let ml: Vec<i32> = (0..256).map(|_| rng.range_i32(-128, 127)).collect();
    let r = bench("mac_dot booth len=256 @8b", cfg, || {
        mac_dot(MacVariant::Booth, &mc, &ml, 8, 48)
    });
    println!("{}   ({} Mcycle/s simulated)", r.format(), fmt_rate(r.per_second(257.0 * 8.0) / 1e6));

    // ---- 2. full SA matmul (the simulator inner loop) -------------------
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let (m, k, n, bits) = (4usize, 64usize, 16usize, 8u32);
    let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(-128, 127)).collect();
    let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(-128, 127)).collect();
    let mut arr = SystolicArray::new(sa);
    let cycles = arr.matmul(&a, &b, m, k, n, bits).unwrap().stats.total_cycles();
    let r = bench("sa.matmul 4x64x16 @8b (16x4 array)", cfg, || {
        arr.matmul(&a, &b, m, k, n, bits).unwrap().result[0]
    });
    println!(
        "{}   ({} Msim-cycle/s, {} MACsteps/s)",
        r.format(),
        fmt_rate(r.per_second(cycles as f64) / 1e6),
        fmt_rate(r.per_second(cycles as f64 * 64.0) / 1e6)
    );

    // ---- 3. native Booth-plane matmul (functional fallback) -------------
    let (m2, k2, n2) = (32usize, 128usize, 64usize);
    let a2: Vec<i32> = (0..m2 * k2).map(|_| rng.range_i32(-128, 127)).collect();
    let b2: Vec<i32> = (0..k2 * n2).map(|_| rng.range_i32(-128, 127)).collect();
    let r = bench("matmul_native 32x128x64 @8b", cfg, || {
        matmul_native(&a2, &b2, m2, k2, n2, 8).unwrap()[0]
    });
    let macs = (m2 * k2 * n2) as f64;
    println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs) / 1e6));

    // ---- 4. tiler ---------------------------------------------------------
    let r = bench("tile_matmul 512x512x512 on 16x4", cfg, || {
        tile_matmul(512, 512, 512, &sa).jobs.len()
    });
    println!("{}", r.format());

    // ---- 5. scheduler end-to-end (native backend) ----------------------
    let mut sched = Scheduler::new(sa, Backend::Native);
    let r = bench("scheduler.matmul 32x128x64 @8b native", cfg, || {
        sched.matmul(&a2, &b2, m2, k2, n2, 8).unwrap()[0]
    });
    println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs) / 1e6));

    // ---- 6. PJRT request path (if artifacts are built) ------------------
    let dir = bitsmm::runtime::default_artifact_dir();
    match bitsmm::runtime::EngineHandle::spawn(&dir) {
        Ok((engine, _join)) => {
            engine.warm_up().expect("warm up");
            let a3: Vec<i32> = (0..8 * 64).map(|_| rng.range_i32(-128, 127)).collect();
            let b3: Vec<i32> = (0..64 * 64).map(|_| rng.range_i32(-128, 127)).collect();
            let am = bitsmm::runtime::IntMat::new(a3, 8, 64).unwrap();
            let bm = bitsmm::runtime::IntMat::new(b3, 64, 64).unwrap();
            let r = bench("pjrt mm_booth_b8_8x64x64 round trip", cfg, || {
                engine
                    .execute_matmul(am.clone(), bm.clone(), 8, MacVariant::Booth)
                    .unwrap()
                    .unwrap()[0]
            });
            println!("{}   ({} req/s)", r.format(), fmt_rate(r.per_second(1.0)));
            engine.shutdown();
        }
        Err(e) => println!("pjrt path skipped: {e:#}"),
    }
    println!("\nperf_hotpath bench OK");
}

fn fmt_rate(v: f64) -> String {
    bitsmm::report::f(v)
}
