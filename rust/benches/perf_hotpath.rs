//! Bench PERF: host-side hot-path microbenchmarks feeding the §Perf
//! iteration log — simulator inner loop, native matmul, the per-plane
//! and word-packed plane realisations, the popcount-reducer and
//! thread-count sweeps of the packed engine, the skewed-shape
//! equal-slice vs work-stealing scheduler comparison, the shape-keyed
//! execution planner's planned-vs-best/worst-static sweep, the
//! low-precision RSR-vs-popcount sweep and the huge-k
//! k-split-on/off sweep (the headlines for this PR — both assert the
//! chosen plan never loses to its forced baseline in-bench),
//! cross-precision plane slicing, tiler, and
//! (when artifacts are built) the PJRT request path. Every result is
//! also written to `BENCH_perf_hotpath.json` at the repo root so the
//! perf trajectory is machine-trackable across PRs.
//!
//! Set `BITSMM_BENCH_SMOKE=1` (CI does) to run the same matrix on a
//! small shape with a tight iteration budget — seconds, not minutes —
//! while still producing the JSON artifact.

use bitsmm::bench_harness::{bench, BenchConfig, BenchResult};
use bitsmm::bits::packed::{
    matmul_packed_planes, matmul_packed_rsr, matmul_packed_tile_pooled,
    matmul_packed_tile_rowslice, matmul_packed_tile_stolen, matmul_packed_tile_stolen_with,
    matmul_packed_tile_with, KernelFamily, PackedPlanes, PackedPool, PopcountKernel, TilePolicy,
};
use bitsmm::bits::plane::PlaneKind;
use bitsmm::coordinator::{tile_matmul, Backend, Scheduler};
use bitsmm::nn::{matmul_native, matmul_packed, matmul_planes};
use bitsmm::plan::{codebook_cols, ExecPlan, PlanKey, Planner, PlannerMode, ShapeRun};
use bitsmm::prng::Pcg32;
use bitsmm::sim::array::{SaConfig, SystolicArray};
use bitsmm::sim::driver::mac_dot;
use bitsmm::sim::mac_common::MacVariant;
use std::sync::Arc;

fn main() {
    let smoke = std::env::var("BITSMM_BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    bitsmm::bench_harness::header(
        "perf_hotpath",
        if smoke {
            "host hot paths (SMOKE mode: small shapes, tight budget)"
        } else {
            "host hot paths (native vs planes vs packed; reducer + thread sweeps)"
        },
    );
    let cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            target_time: std::time::Duration::from_millis(50),
        }
    } else {
        BenchConfig::default()
    };
    let mut rng = Pcg32::new(0x9e4f);
    let mut log: Vec<BenchResult> = Vec::new();

    // ---- 1. single-MAC stepping ---------------------------------------
    let mc: Vec<i32> = (0..256).map(|_| rng.range_i32(-128, 127)).collect();
    let ml: Vec<i32> = (0..256).map(|_| rng.range_i32(-128, 127)).collect();
    let r = bench("mac_dot booth len=256 @8b", cfg, || {
        mac_dot(MacVariant::Booth, &mc, &ml, 8, 48)
    });
    println!("{}   ({} Mcycle/s simulated)", r.format(), fmt_rate(r.per_second(257.0 * 8.0) / 1e6));
    log.push(r);

    // ---- 2. full SA matmul (the simulator inner loop) -------------------
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let (m, k, n, bits) = (4usize, 64usize, 16usize, 8u32);
    let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(-128, 127)).collect();
    let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(-128, 127)).collect();
    let mut arr = SystolicArray::new(sa);
    let cycles = arr.matmul(&a, &b, m, k, n, bits).unwrap().stats.total_cycles();
    let r = bench("sa.matmul 4x64x16 @8b (16x4 array)", cfg, || {
        arr.matmul(&a, &b, m, k, n, bits).unwrap().result[0]
    });
    println!(
        "{}   ({} Msim-cycle/s, {} MACsteps/s)",
        r.format(),
        fmt_rate(r.per_second(cycles as f64) / 1e6),
        fmt_rate(r.per_second(cycles as f64 * 64.0) / 1e6)
    );
    log.push(r);

    // ---- 3. native vs per-plane vs packed, bit-width sweep --------------
    // The packed engine's plane-pair count grows with bits² while its
    // word count shrinks 64×, so the sweep shows where each
    // realisation wins (see DESIGN.md §Packed-Planes).
    let (m2, k2, n2) = if smoke {
        (16usize, 128usize, 16usize)
    } else {
        (64usize, 128usize, 64usize)
    };
    let macs2 = (m2 * k2 * n2) as f64;
    for bits in [1u32, 2, 4, 8, 16] {
        let lo = bitsmm::bits::twos::min_value(bits);
        let hi = bitsmm::bits::twos::max_value(bits);
        let a2: Vec<i32> = (0..m2 * k2).map(|_| rng.range_i32(lo, hi)).collect();
        let b2: Vec<i32> = (0..k2 * n2).map(|_| rng.range_i32(lo, hi)).collect();
        for (name, f) in [
            ("native", matmul_native as fn(&[i32], &[i32], usize, usize, usize, u32) -> bitsmm::Result<Vec<i64>>),
            ("planes", matmul_planes),
            ("packed", matmul_packed),
        ] {
            let r = bench(&format!("matmul_{name} {m2}x{k2}x{n2} @{bits}b"), cfg, || {
                f(&a2, &b2, m2, k2, n2, bits).unwrap()[0]
            });
            println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs2) / 1e6));
            log.push(r);
        }
    }

    // ---- 4. the acceptance matrix -----------------------------------------
    // (bigger problem, fewer iterations; packed must beat planes here,
    // and the threaded packed kernel must beat scalar single-thread by
    // >= 2x at >= 4 threads)
    let big = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 2,
            target_time: std::time::Duration::from_millis(40),
        }
    } else {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            target_time: std::time::Duration::from_millis(400),
        }
    };
    let dim = if smoke { 64usize } else { 256usize };
    let (m3, k3, n3, bits3) = (dim, dim, dim, 8u32);
    let shape3 = format!("{m3}x{k3}x{n3}");
    let macs3 = (m3 * k3 * n3) as f64;
    let a3: Vec<i32> = (0..m3 * k3).map(|_| rng.range_i32(-128, 127)).collect();
    let b3: Vec<i32> = (0..k3 * n3).map(|_| rng.range_i32(-128, 127)).collect();
    let mut native_mean = 0f64;
    let mut planes_mean = 0f64;
    let mut packed_mean = 0f64;
    for (name, f) in [
        ("native", matmul_native as fn(&[i32], &[i32], usize, usize, usize, u32) -> bitsmm::Result<Vec<i64>>),
        ("planes", matmul_planes),
        ("packed", matmul_packed),
    ] {
        let r = bench(&format!("matmul_{name} {shape3} @{bits3}b"), big, || {
            f(&a3, &b3, m3, k3, n3, bits3).unwrap()[0]
        });
        println!("{}   ({} GOPS)", r.format(), fmt_rate(r.per_second(macs3) / 1e9));
        match name {
            "native" => native_mean = r.mean.as_secs_f64(),
            "planes" => planes_mean = r.mean.as_secs_f64(),
            "packed" => packed_mean = r.mean.as_secs_f64(),
            _ => {}
        }
        log.push(r);
    }
    if packed_mean > 0.0 && planes_mean > 0.0 {
        println!(
            "packed vs per-plane speedup @8b {shape3}: {:.2}x",
            planes_mean / packed_mean
        );
    }

    // ---- 5. packed kernel with pre-packed (cached) weights --------------
    // the serving steady state: only the streamed operand packs per call
    let pb = PackedPlanes::pack_cols(&b3, k3, n3, bits3, PlaneKind::Sbmwc).unwrap();
    let r = bench(&format!("matmul_packed {shape3} @{bits3}b cached-W"), big, || {
        let pa = PackedPlanes::pack_rows(&a3, m3, k3, bits3, PlaneKind::Sbmwc).unwrap();
        matmul_packed_planes(&pa, &pb).unwrap()[0]
    });
    println!("{}   ({} GOPS)", r.format(), fmt_rate(r.per_second(macs3) / 1e9));
    log.push(r);

    // ---- 5b. popcount reducer sweep (single thread, both cached) --------
    // scalar = the PR 1 kernel, the baseline for the acceptance speedup
    let pa3 = Arc::new(PackedPlanes::pack_rows(&a3, m3, k3, bits3, PlaneKind::Sbmwc).unwrap());
    let pb3 = Arc::new(pb);
    let mut scalar_mean = 0f64;
    for kernel in PopcountKernel::CONCRETE {
        if !kernel.available() {
            println!("packed {shape3} @{bits3}b t1 {:<8}  skipped (CPU lacks it)", kernel.name());
            continue;
        }
        let r = bench(&format!("packed {shape3} @{bits3}b t1 {}", kernel.name()), big, || {
            matmul_packed_tile_with(&pa3, &pb3, 0, m3, 0, n3, kernel).unwrap()[0]
        });
        let mean = r.mean.as_secs_f64();
        if kernel == PopcountKernel::Scalar {
            scalar_mean = mean;
        }
        println!(
            "{}   ({} GOPS, {:.2}x vs scalar, {:.2}x vs native)",
            r.format(),
            fmt_rate(r.per_second(macs3) / 1e9),
            safe_ratio(scalar_mean, mean),
            safe_ratio(native_mean, mean)
        );
        log.push(r);
    }

    // ---- 5c. thread sweep on the shared row-block pool ------------------
    // (auto reducer; pools are persistent — built once, reused per run)
    let mut t4_mean = 0f64;
    for threads in [1usize, 2, 4, 8] {
        let pool = PackedPool::new(threads).unwrap();
        let r = bench(&format!("packed {shape3} @{bits3}b t{threads} auto"), big, || {
            matmul_packed_tile_pooled(&pool, &pa3, &pb3, 0, m3, 0, n3, PopcountKernel::Auto)
                .unwrap()[0]
        });
        let mean = r.mean.as_secs_f64();
        if threads == 4 {
            t4_mean = mean;
        }
        println!(
            "{}   ({} GOPS, {:.2}x vs t1-scalar, {:.2}x vs native)",
            r.format(),
            fmt_rate(r.per_second(macs3) / 1e9),
            safe_ratio(scalar_mean, mean),
            safe_ratio(native_mean, mean)
        );
        log.push(r);
    }
    if scalar_mean > 0.0 && t4_mean > 0.0 {
        println!(
            "ACCEPTANCE packed {shape3} @8b: t4 vs PR1 scalar t1 = {:.2}x (target >= 2x)",
            scalar_mean / t4_mean
        );
    }

    // ---- 5c'. scheduling geometry: equal row slices vs 2-D stealing -----
    // The PR 2 partitioner (`min(threads, rows)` equal row slices)
    // against the work-stealing 2-D tile scheduler, at 8 threads, on
    // skewed shapes (single-row serving, single-column projections,
    // wide-K attention blocks) plus the square no-regression shape.
    // Both paths must stay bit-identical to the serial kernel.
    // Arc-wrapped: the 5c'' ShapeRun below shares it by &Arc; the
    // direct kernel calls in this section auto-deref through it.
    let pool8 = Arc::new(PackedPool::new(8).unwrap());
    let skew_shapes: &[(usize, usize, usize)] = if smoke {
        &[(1, 128, 512), (512, 128, 1), (16, 512, 16), (64, 64, 64)]
    } else {
        &[(1, 512, 4096), (4096, 512, 1), (64, 4096, 64), (256, 256, 256)]
    };
    for &(sm, sk, sn) in skew_shapes {
        let lbl = format!("{sm}x{sk}x{sn}");
        let smacs = (sm * sk * sn) as f64;
        let sa_m: Vec<i32> = (0..sm * sk).map(|_| rng.range_i32(-128, 127)).collect();
        let sb_m: Vec<i32> = (0..sk * sn).map(|_| rng.range_i32(-128, 127)).collect();
        let pa = Arc::new(PackedPlanes::pack_rows(&sa_m, sm, sk, 8, PlaneKind::Sbmwc).unwrap());
        let pb = Arc::new(PackedPlanes::pack_cols(&sb_m, sk, sn, 8, PlaneKind::Sbmwc).unwrap());
        // bit-identity first: serial == rowslice == stolen
        let serial_out =
            matmul_packed_tile_with(&pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto).unwrap();
        let rowslice_out =
            matmul_packed_tile_rowslice(&pool8, &pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto)
                .unwrap();
        let (stolen_out, stats) = matmul_packed_tile_stolen(
            &pool8, &pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto, TilePolicy::AUTO,
        )
        .unwrap();
        assert_eq!(rowslice_out, serial_out, "rowslice diverged on {lbl}");
        assert_eq!(stolen_out, serial_out, "steal2d diverged on {lbl}");
        let r = bench(&format!("packed {lbl} @8b t8 rowslice"), big, || {
            matmul_packed_tile_rowslice(&pool8, &pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto)
                .unwrap()[0]
        });
        let rowslice_mean = r.mean.as_secs_f64();
        println!("{}   ({} GOPS)", r.format(), fmt_rate(r.per_second(smacs) / 1e9));
        log.push(r);
        let r = bench(&format!("packed {lbl} @8b t8 steal2d"), big, || {
            matmul_packed_tile_pooled(&pool8, &pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto)
                .unwrap()[0]
        });
        let stolen_mean = r.mean.as_secs_f64();
        println!(
            "{}   ({} GOPS, {:.2}x vs rowslice)",
            r.format(),
            fmt_rate(r.per_second(smacs) / 1e9),
            safe_ratio(rowslice_mean, stolen_mean),
        );
        // steal/share numbers are scheduling-dependent and vary run to
        // run; these come from the single correctness run above, not
        // from the timed iterations
        println!(
            "  steal2d sample run: {} tiles, {} steals, worker share {}..{}",
            stats.tiles, stats.steals, stats.min_worker_tiles, stats.max_worker_tiles
        );
        log.push(r);
        let tag = if sm == sk && sk == sn { "no-regression" } else { "skew" };
        println!(
            "ACCEPTANCE {tag} {lbl} @8b t8: steal2d vs equal-slice = {:.2}x (bit-identical: yes)",
            safe_ratio(rowslice_mean, stolen_mean)
        );
    }

    // ---- 5c''. shape-keyed planner: planned vs best/worst static --------
    // Every candidate ExecPlan is a static configuration someone could
    // have deployed server-wide. The planner must never lose to the
    // worst of them on any swept shape, and must match (or beat, via
    // per-shape re-planning) the single best static config across the
    // whole skewed set — the acceptance bar for making the planner the
    // serving default. Candidate outputs are asserted bit-identical to
    // the serial kernel before anything is timed.
    let plan_cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 2,
            target_time: std::time::Duration::from_millis(30),
        }
    } else {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            target_time: std::time::Duration::from_millis(100),
        }
    };
    let slots = pool8.threads() + 1;
    let planner = Planner::new(PlannerMode::Online, slots);
    let candidates = ExecPlan::candidates(slots);
    // sums of per-shape mean times: the cross-shape acceptance compares
    // the planner against the best single static config applied to ALL
    // shapes, which is what a static deployment would have to do
    let mut planned_total = 0f64;
    let mut worst_case_ok = true;
    let mut static_totals = vec![0f64; candidates.len()];
    for &(sm, sk, sn) in skew_shapes {
        let lbl = format!("{sm}x{sk}x{sn}");
        let smacs = (sm * sk * sn) as f64;
        let sa_m: Vec<i32> = (0..sm * sk).map(|_| rng.range_i32(-128, 127)).collect();
        let sb_m: Vec<i32> = (0..sk * sn).map(|_| rng.range_i32(-128, 127)).collect();
        let pb = Arc::new(PackedPlanes::pack_cols(&sb_m, sk, sn, 8, PlaneKind::Sbmwc).unwrap());
        let run = ShapeRun {
            a: &sa_m,
            b: &sb_m,
            m: sm,
            k: sk,
            n: sn,
            bits: 8,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: Some(&pb),
            pool: Some(&pool8),
        };
        let want = matmul_packed_tile_with(
            &PackedPlanes::pack_rows(&sa_m, sm, sk, 8, PlaneKind::Sbmwc).unwrap(),
            &pb,
            0,
            sm,
            0,
            sn,
            PopcountKernel::Auto,
        )
        .unwrap();
        let mut best = f64::INFINITY;
        let mut best_label = String::new();
        let mut worst = 0f64;
        for (ci, plan) in candidates.iter().enumerate() {
            let (out, _, _) = run.run(plan).unwrap();
            assert_eq!(out, want, "{} diverged on {lbl}", plan.label());
            let r = bench(&format!("plan {lbl} @8b {}", plan.label()), plan_cfg, || {
                run.run(plan).unwrap().0[0]
            });
            let mean = r.mean.as_secs_f64();
            println!("{}   ({} GOPS)", r.format(), fmt_rate(r.per_second(smacs) / 1e9));
            log.push(r); // every static baseline reaches the JSON trajectory
            static_totals[ci] += mean;
            if mean < best {
                best = mean;
                best_label = plan.label();
            }
            worst = worst.max(mean);
        }
        // calibrate this shape explicitly (plan_run could resolve a
        // nearby swept shape at the nearest tier instead), then bench
        // the planned configuration like the statics were
        let key = PlanKey::for_matmul(sm, sk, sn, 8, 8, PlaneKind::Sbmwc);
        let (plan, cal_out) = planner.calibrate(key, &run).unwrap();
        assert_eq!(cal_out.0, want, "planned {lbl}");
        let r = bench(&format!("plan {lbl} @8b PLANNED {}", plan.label()), plan_cfg, || {
            run.run(&plan).unwrap().0[0]
        });
        let planned = r.mean.as_secs_f64();
        planned_total += planned;
        println!(
            "{}   ({} GOPS)",
            r.format(),
            fmt_rate(r.per_second(smacs) / 1e9)
        );
        log.push(r);
        if planned > worst {
            worst_case_ok = false;
        }
        println!(
            "ACCEPTANCE planner {lbl} @8b: planned [{}] = {:.2}x vs best [{best_label}], \
{:.2}x vs worst (planned-never-worst: {})",
            plan.label(),
            safe_ratio(best, planned),
            safe_ratio(worst, planned),
            if planned <= worst { "yes" } else { "NO" },
        );
    }
    let best_static_total = static_totals.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "ACCEPTANCE planner aggregate over the skewed set: planned vs best single static \
config = {:.2}x (>= 1.00x required), never-slower-than-worst on every shape: {}",
        safe_ratio(best_static_total, planned_total),
        if worst_case_ok { "yes" } else { "NO" },
    );

    // ---- 5d. sub-popcount low-precision sweep: RSR vs popcount ----------
    // The 1–2 bit regime where quantized weight columns repeat: the RSR
    // segment kernel dedupes the stationary operand's column
    // word-patterns and serves outputs from shared segment dots. The
    // stationary operand draws from a 16-column codebook (the regime
    // real low-bit weights live in); the planner calibrates on the live
    // operands, and its chosen plan must never lose to the forced
    // popcount baselines beyond timing noise — asserted in-bench.
    let lowprec_shapes: &[(usize, usize, usize)] = if smoke {
        &[(64, 256, 64), (16, 512, 16)]
    } else {
        &[(256, 256, 256), (64, 4096, 64)]
    };
    for &(sm, sk, sn) in lowprec_shapes {
        for bits in [1u32, 2] {
            let lbl = format!("{sm}x{sk}x{sn}");
            let smacs = (sm * sk * sn) as f64;
            let lo = bitsmm::bits::twos::min_value(bits);
            let hi = bitsmm::bits::twos::max_value(bits);
            let sa_m: Vec<i32> = (0..sm * sk).map(|_| rng.range_i32(lo, hi)).collect();
            let sb_m = codebook_cols(&mut rng, sk, sn, lo, hi, 16);
            let pa = Arc::new(PackedPlanes::pack_rows(&sa_m, sm, sk, bits, PlaneKind::Sbmwc).unwrap());
            let pb = Arc::new(PackedPlanes::pack_cols(&sb_m, sk, sn, bits, PlaneKind::Sbmwc).unwrap());
            // bit-identity before anything is timed
            let want =
                matmul_packed_tile_with(&pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto).unwrap();
            let rsr_out =
                matmul_packed_rsr(&pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto, 0).unwrap();
            assert_eq!(rsr_out, want, "rsr diverged on {lbl} @{bits}b");
            let r = bench(&format!("lowprec {lbl} @{bits}b popcount t1"), big, || {
                matmul_packed_tile_with(&pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto).unwrap()[0]
            });
            let pop_serial = r.mean.as_secs_f64();
            println!("{}   ({} GMAC/s)", r.format(), fmt_rate(r.per_second(smacs) / 1e9));
            log.push(r);
            let r = bench(&format!("lowprec {lbl} @{bits}b popcount t8 steal2d"), big, || {
                matmul_packed_tile_pooled(&pool8, &pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto)
                    .unwrap()[0]
            });
            let pop_pooled = r.mean.as_secs_f64();
            println!("{}   ({} GMAC/s)", r.format(), fmt_rate(r.per_second(smacs) / 1e9));
            log.push(r);
            let r = bench(&format!("lowprec {lbl} @{bits}b rsr t1"), big, || {
                matmul_packed_rsr(&pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto, 0).unwrap()[0]
            });
            let rsr_serial = r.mean.as_secs_f64();
            println!(
                "{}   ({} GMAC/s, {:.2}x vs popcount t1)",
                r.format(),
                fmt_rate(r.per_second(smacs) / 1e9),
                safe_ratio(pop_serial, rsr_serial)
            );
            log.push(r);
            // the planner's chosen plan, calibrated on these operands
            let run = ShapeRun {
                a: &sa_m,
                b: &sb_m,
                m: sm,
                k: sk,
                n: sn,
                bits,
                stream_kind: PlaneKind::Sbmwc,
                packed_b: Some(&pb),
                pool: Some(&pool8),
            };
            let key = PlanKey::for_matmul(sm, sk, sn, bits, bits, PlaneKind::Sbmwc);
            let (plan, cal_out) = planner.calibrate(key, &run).unwrap();
            assert_eq!(cal_out.0, want, "chosen plan diverged on {lbl} @{bits}b");
            let r = bench(&format!("lowprec {lbl} @{bits}b CHOSEN {}", plan.label()), big, || {
                run.run(&plan).unwrap().0[0]
            });
            let chosen = r.mean.as_secs_f64();
            println!("{}   ({} GMAC/s)", r.format(), fmt_rate(r.per_second(smacs) / 1e9));
            log.push(r);
            // never slower than the forced popcount baseline (best of
            // serial/pooled), with a noise margin for CI boxes
            let pop_best = pop_serial.min(pop_pooled);
            assert!(
                chosen <= pop_best * 1.25,
                "chosen plan [{}] lost to forced popcount on {lbl} @{bits}b: {:.3}ms vs {:.3}ms",
                plan.label(),
                chosen * 1e3,
                pop_best * 1e3
            );
            println!(
                "ACCEPTANCE lowprec {lbl} @{bits}b: chosen [{}] vs forced popcount = {:.2}x, \
vs rsr-t1 = {:.2}x (never-slower-than-popcount: yes)",
                plan.label(),
                safe_ratio(pop_best, chosen),
                safe_ratio(rsr_serial, chosen),
            );
        }
    }

    // ---- 5e. huge-k sweep: deterministic k-split on/off -----------------
    // 1×hugek×n shapes leave a 2-D tile grid starved (few output cells,
    // enormous contracted dimension): k-split fans word-aligned chunks
    // across the pool's slots and merges exact i64 partials in fixed
    // job-index order. The chosen plan must never lose to the forced
    // no-split baseline — asserted in-bench.
    let hugek_shapes: &[(usize, usize, usize)] = if smoke {
        &[(1, 4096, 64), (4, 8192, 16)]
    } else {
        &[(1, 8192, 512), (16, 16384, 64)]
    };
    for &(sm, sk, sn) in hugek_shapes {
        let lbl = format!("{sm}x{sk}x{sn}");
        let smacs = (sm * sk * sn) as f64;
        let sa_m: Vec<i32> = (0..sm * sk).map(|_| rng.range_i32(-128, 127)).collect();
        let sb_m: Vec<i32> = (0..sk * sn).map(|_| rng.range_i32(-128, 127)).collect();
        let pa = Arc::new(PackedPlanes::pack_rows(&sa_m, sm, sk, 8, PlaneKind::Sbmwc).unwrap());
        let pb = Arc::new(PackedPlanes::pack_cols(&sb_m, sk, sn, 8, PlaneKind::Sbmwc).unwrap());
        let want = matmul_packed_tile_with(&pa, &pb, 0, sm, 0, sn, PopcountKernel::Auto).unwrap();
        let (nosplit_out, _) = matmul_packed_tile_stolen_with(
            &pool8, &pa, &pb, 0, sm, 0, sn,
            PopcountKernel::Auto, TilePolicy::NO_KSPLIT, KernelFamily::Popcount,
        )
        .unwrap();
        assert_eq!(nosplit_out, want, "no-split diverged on {lbl}");
        let (split_out, stats) = matmul_packed_tile_stolen_with(
            &pool8, &pa, &pb, 0, sm, 0, sn,
            PopcountKernel::Auto, TilePolicy::AUTO, KernelFamily::Popcount,
        )
        .unwrap();
        assert_eq!(split_out, want, "k-split diverged on {lbl}");
        let r = bench(&format!("hugek {lbl} @8b t8 no-ksplit"), big, || {
            matmul_packed_tile_stolen_with(
                &pool8, &pa, &pb, 0, sm, 0, sn,
                PopcountKernel::Auto, TilePolicy::NO_KSPLIT, KernelFamily::Popcount,
            )
            .unwrap()
            .0[0]
        });
        let nosplit = r.mean.as_secs_f64();
        println!("{}   ({} GOPS)", r.format(), fmt_rate(r.per_second(smacs) / 1e9));
        log.push(r);
        let r = bench(&format!("hugek {lbl} @8b t8 ksplit-auto"), big, || {
            matmul_packed_tile_stolen_with(
                &pool8, &pa, &pb, 0, sm, 0, sn,
                PopcountKernel::Auto, TilePolicy::AUTO, KernelFamily::Popcount,
            )
            .unwrap()
            .0[0]
        });
        let auto_split = r.mean.as_secs_f64();
        println!(
            "{}   ({} GOPS, {:.2}x vs no-split; sample run: {} jobs, {} steals)",
            r.format(),
            fmt_rate(r.per_second(smacs) / 1e9),
            safe_ratio(nosplit, auto_split),
            stats.tiles,
            stats.steals,
        );
        log.push(r);
        // the planner's chosen plan, calibrated on these operands
        let run = ShapeRun {
            a: &sa_m,
            b: &sb_m,
            m: sm,
            k: sk,
            n: sn,
            bits: 8,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: Some(&pb),
            pool: Some(&pool8),
        };
        let key = PlanKey::for_matmul(sm, sk, sn, 8, 8, PlaneKind::Sbmwc);
        let (plan, cal_out) = planner.calibrate(key, &run).unwrap();
        assert_eq!(cal_out.0, want, "chosen plan diverged on {lbl}");
        let r = bench(&format!("hugek {lbl} @8b CHOSEN {}", plan.label()), big, || {
            run.run(&plan).unwrap().0[0]
        });
        let chosen = r.mean.as_secs_f64();
        println!("{}   ({} GOPS)", r.format(), fmt_rate(r.per_second(smacs) / 1e9));
        log.push(r);
        assert!(
            chosen <= nosplit * 1.25,
            "chosen plan [{}] lost to forced no-split on {lbl}: {:.3}ms vs {:.3}ms",
            plan.label(),
            chosen * 1e3,
            nosplit * 1e3
        );
        println!(
            "ACCEPTANCE hugek {lbl} @8b: chosen [{}] vs forced no-split = {:.2}x, \
auto-ksplit vs no-split = {:.2}x (never-slower-than-no-split: yes)",
            plan.label(),
            safe_ratio(nosplit, chosen),
            safe_ratio(nosplit, auto_split),
        );
    }

    // ---- 5f. cross-precision plane reuse: slice vs fresh re-pack --------
    // 4-bit-range weights packed at 8 bits: a precision-lowered request
    // slices a plane-subset view where PR 1 re-decomposed the matrix
    let b_lo: Vec<i32> = (0..k3 * n3).map(|_| rng.range_i32(-8, 7)).collect();
    let pb_wide = PackedPlanes::pack_cols(&b_lo, k3, n3, 8, PlaneKind::Sbmwc).unwrap();
    let r = bench(&format!("pack_cols {k3}x{n3} @4b (fresh re-pack)"), big, || {
        PackedPlanes::pack_cols(&b_lo, k3, n3, 4, PlaneKind::Sbmwc).unwrap().words
    });
    println!("{}", r.format());
    log.push(r);
    let r = bench(&format!("slice_bits 8->4 {k3}x{n3} (plane-subset view)"), big, || {
        pb_wide.slice_bits(4).unwrap().bits
    });
    println!("{}   (replaces the fresh re-pack above)", r.format());
    log.push(r);

    // ---- 6. tiler ---------------------------------------------------------
    let r = bench("tile_matmul 512x512x512 on 16x4", cfg, || {
        tile_matmul(512, 512, 512, &sa).jobs.len()
    });
    println!("{}", r.format());
    log.push(r);

    // ---- 7. scheduler end-to-end (native + packed backends) -------------
    let (m4, k4, n4) = (32usize, 128usize, 64usize);
    let macs4 = (m4 * k4 * n4) as f64;
    let a4: Vec<i32> = (0..m4 * k4).map(|_| rng.range_i32(-128, 127)).collect();
    let b4: Vec<i32> = (0..k4 * n4).map(|_| rng.range_i32(-128, 127)).collect();
    for backend in [Backend::Native, Backend::Packed] {
        let name = backend.name();
        let mut sched = Scheduler::new(sa, backend);
        let r = bench(&format!("scheduler.matmul 32x128x64 @8b {name}"), cfg, || {
            sched.matmul(&a4, &b4, m4, k4, n4, 8).unwrap()[0]
        });
        println!("{}   ({} MMAC/s)", r.format(), fmt_rate(r.per_second(macs4) / 1e6));
        log.push(r);
    }

    // ---- 8. PJRT request path (if artifacts are built) ------------------
    let dir = bitsmm::runtime::default_artifact_dir();
    match bitsmm::runtime::EngineHandle::spawn(&dir) {
        Ok((engine, _join)) => {
            engine.warm_up().expect("warm up");
            let a5: Vec<i32> = (0..8 * 64).map(|_| rng.range_i32(-128, 127)).collect();
            let b5: Vec<i32> = (0..64 * 64).map(|_| rng.range_i32(-128, 127)).collect();
            let am = bitsmm::runtime::IntMat::new(a5, 8, 64).unwrap();
            let bm = bitsmm::runtime::IntMat::new(b5, 64, 64).unwrap();
            let r = bench("pjrt mm_booth_b8_8x64x64 round trip", cfg, || {
                engine
                    .execute_matmul(am.clone(), bm.clone(), 8, MacVariant::Booth)
                    .unwrap()
                    .unwrap()[0]
            });
            println!("{}   ({} req/s)", r.format(), fmt_rate(r.per_second(1.0)));
            log.push(r);
            engine.shutdown();
        }
        Err(e) => println!("pjrt path skipped: {e:#}"),
    }

    match bitsmm::bench_harness::write_json("perf_hotpath", &log) {
        Ok(path) => println!("\nwrote {path} ({} results)", log.len()),
        Err(e) => println!("\ncould not write bench json: {e}"),
    }
    println!("perf_hotpath bench OK");
}

fn fmt_rate(v: f64) -> String {
    bitsmm::report::f(v)
}

/// `num/den` guarded against a zero denominator/numerator (skipped
/// baseline entries), so a missing baseline prints 0.00x, not inf.
fn safe_ratio(num: f64, den: f64) -> f64 {
    if num > 0.0 && den > 0.0 {
        num / den
    } else {
        0.0
    }
}
