//! Bench FIG6: regenerate paper Fig. 6 — peak throughput (OP/cycle) vs
//! operand bit width for the 16×4 / 32×8 / 64×16 topologies (eq. 10) —
//! and cross-validate the analytic peaks against achieved throughput
//! measured on the cycle-accurate simulator at long vector lengths.

use bitsmm::arch::throughput::peak_op_per_cycle;
use bitsmm::coordinator::{Backend, Scheduler};
use bitsmm::report::{f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;

fn main() {
    bitsmm::bench_harness::header(
        "fig6_peak_throughput",
        "paper Fig. 6: peak OP/cycle vs bit width (eq. 10) + simulator cross-check",
    );
    print!("{}", bitsmm::report::paper::render_fig6());

    // Cross-check: achieved OP/cycle on the simulator approaches the
    // analytic peak as the contracted dimension grows (n → ∞ claim).
    let mut t = Table::new(
        "simulator cross-check (achieved/peak at k=512, full-size tiles)",
        &["SA", "bits", "peak OP/c", "achieved OP/c", "ratio"],
    );
    for (cols, rows) in [(16usize, 4usize), (32, 8)] {
        for bits in [4u32, 8, 16] {
            let sa = SaConfig::new(rows, cols, MacVariant::Booth);
            let (m, k, n) = (rows, 512usize, cols);
            let a = vec![1i32; m * k];
            let b = vec![-1i32; k * n];
            let mut sched = Scheduler::new(sa, Backend::Simulate);
            sched.matmul(&a, &b, m, k, n, bits).expect("sim matmul");
            let achieved = sched.report.macs as f64 / sched.report.hw_cycles as f64;
            let peak = peak_op_per_cycle(cols as u64, rows as u64, bits);
            let ratio = achieved / peak;
            t.row(&[
                sa.label(),
                bits.to_string(),
                f(peak),
                f(achieved),
                f(ratio),
            ]);
            assert!(
                ratio > 0.80 && ratio <= 1.0,
                "{} @{bits}b: achieved/peak = {ratio}",
                sa.label()
            );
        }
    }
    print!("{}", t.render());
    println!("fig6 bench OK (shape matches eq. 10; simulator within 20% of peak at k=512)");
}
