//! Bench T2: regenerate paper Table II (AMD ZCU104 FPGA @ 300 MHz) from
//! the calibrated resource/power model, assert the paper's qualitative
//! findings, and report residuals against the published numbers.

use bitsmm::arch::fpga::FpgaModel;
use bitsmm::report::{f, Table};

const PAPER: [(&str, u64, u64, f64, f64, f64); 4] = [
    ("16x4", 5630, 8762, 1.13, 1.2, 1.062),
    ("16x4 SBMwC", 11418, 10807, 1.657, 1.2, 0.724),
    ("32x8", 29355, 35490, 2.125, 4.8, 2.259),
    ("64x16", 117836, 155586, 6.459, 19.2, 2.973),
];

fn main() {
    bitsmm::bench_harness::header("table2_fpga", "paper Table II: FPGA implementation results");
    print!("{}", bitsmm::report::paper::render_table2());

    let rows = FpgaModel::default().table2_rows();
    let mut t = Table::new(
        "residuals vs paper",
        &["design", "LUT err", "FF err", "power err", "GOPS err", "GOPS/W err"],
    );
    let mut worst: f64 = 0.0;
    for (row, p) in rows.iter().zip(PAPER) {
        let e = [
            rel(row.luts as f64, p.1 as f64),
            rel(row.ffs as f64, p.2 as f64),
            rel(row.power_w, p.3),
            rel(row.gops, p.4),
            rel(row.gops_per_w, p.5),
        ];
        worst = e.iter().fold(worst, |a, &b| a.max(b));
        t.row(&[
            p.0.into(),
            pct(e[0]),
            pct(e[1]),
            pct(e[2]),
            pct(e[3]),
            pct(e[4]),
        ]);
    }
    print!("{}", t.render());

    // the paper's qualitative findings must reproduce exactly
    assert!(rows[1].luts > rows[0].luts, "SBMwC uses more LUTs");
    assert!(rows[0].gops_per_w > rows[1].gops_per_w, "Booth wins GOPS/W");
    assert!(
        rows[3].gops_per_w > rows[2].gops_per_w && rows[2].gops_per_w > rows[0].gops_per_w,
        "GOPS/W increases with array size on FPGA"
    );
    assert!(worst < 0.09, "worst residual {worst}");
    println!("table2 bench OK (worst residual {})", pct(worst));
}

fn rel(got: f64, want: f64) -> f64 {
    (got - want).abs() / want
}

fn pct(e: f64) -> String {
    format!("{}%", f(e * 100.0))
}
