//! Bench CYC: validate the paper's cycle equations against *measured*
//! simulator cycles across the three published topologies — the
//! eq. 8/9 sanity that the paper takes from its RTL testbenches. Also
//! times the simulator itself (host-side cost of cycle accuracy).

use bitsmm::bench_harness::{bench, BenchConfig};
use bitsmm::coordinator::tile_matmul;
use bitsmm::report::{f, Table};
use bitsmm::sim::array::{SaConfig, SystolicArray};
use bitsmm::sim::mac_common::MacVariant;

fn main() {
    bitsmm::bench_harness::header(
        "sim_cycle_accuracy",
        "measured simulator cycles vs the paper's analytic model (eq. 8 + readout)",
    );
    let mut t = Table::new(
        "measured vs modelled cycles (full-size tiles)",
        &["SA", "k", "bits", "measured", "eq8+fill+readout", "delta", "delta %"],
    );
    let mut worst_pct = 0.0f64;
    for (cols, rows) in [(16usize, 4usize), (32, 8), (64, 16)] {
        let sa = SaConfig::new(rows, cols, MacVariant::Booth);
        for (k, bits) in [(32usize, 4u32), (128, 8), (512, 16)] {
            let (m, n) = (rows, cols);
            let a = vec![3i32; m * k];
            let b = vec![-2i32; k * n];
            let mut arr = SystolicArray::new(sa);
            let out = arr.matmul(&a, &b, m, k, n, bits).expect("sim");
            let measured = out.stats.total_cycles();
            let modelled = tile_matmul(m, k, n, &sa).total_cycles(&sa, bits);
            let delta = measured as i64 - modelled as i64;
            let pct = delta.unsigned_abs() as f64 / modelled as f64 * 100.0;
            worst_pct = worst_pct.max(pct);
            t.row(&[
                sa.label(),
                k.to_string(),
                bits.to_string(),
                measured.to_string(),
                modelled.to_string(),
                delta.to_string(),
                f(pct),
            ]);
            assert!(pct < 5.0, "{} k={k} b={bits}: {pct}%", sa.label());
        }
    }
    print!("{}", t.render());
    println!("worst model error: {}% (paper's eq. 9 ignores the systolic fill; the sim measures it)\n", f(worst_pct));

    // host-side simulator throughput (feeds the §Perf log)
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let (m, k, n, bits) = (4usize, 64usize, 16usize, 8u32);
    let a = vec![7i32; m * k];
    let b = vec![-7i32; k * n];
    let mut arr = SystolicArray::new(sa);
    let r = bench("simulate 4x64x16 @8b on 16x4", BenchConfig::default(), || {
        arr.matmul(&a, &b, m, k, n, bits).unwrap().stats.total_cycles()
    });
    println!("{}", r.format());
    let cycles = arr.matmul(&a, &b, m, k, n, bits).unwrap().stats.total_cycles();
    println!(
        "host rate: {} simulated cycles/s ({} cycles per call)",
        f(cycles as f64 / r.mean.as_secs_f64()),
        cycles
    );
    println!("sim_cycle_accuracy bench OK");
}
