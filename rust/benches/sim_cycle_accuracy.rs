//! Bench CYC: validate the paper's cycle equations against *measured*
//! simulator cycles across the three published topologies — the
//! eq. 8/9 sanity that the paper takes from its RTL testbenches — and
//! measure what the instruction-driven device driver wins by
//! overlapping tile N+1's fetch with tile N's execute (DESIGN.md
//! §Device). Also times the simulator itself (host-side cost of cycle
//! accuracy).
//!
//! Set `BITSMM_BENCH_SMOKE=1` (CI does) to shrink the shape matrix and
//! the timing budget. Cycle counts are deterministic, so every
//! assertion still runs in smoke mode.
//!
//! Writes `BENCH_sim_cycle.json` at the repo root. Cycle metrics ride
//! in the same `BenchResult` rows as the wall-clock timings by encoding
//! *cycles as nanoseconds* (1 cycle == 1 ns, i.e. a 1 GHz notional
//! clock); such rows are suffixed `(cycles-as-ns)`.

use bitsmm::bench_harness::{bench, BenchConfig, BenchResult};
use bitsmm::coordinator::tile_matmul;
use bitsmm::device::device_matmul;
use bitsmm::prng::Pcg32;
use bitsmm::report::{f, Table};
use bitsmm::sim::array::{SaConfig, SystolicArray};
use bitsmm::sim::mac_common::MacVariant;
use std::time::Duration;

/// One deterministic cycle metric as a `BenchResult` row (see module
/// doc: cycles encoded as nanoseconds, one "iteration").
fn cycle_row(name: &str, cycles: u64) -> BenchResult {
    let d = Duration::from_nanos(cycles);
    BenchResult {
        name: format!("{name} (cycles-as-ns)"),
        iters: 1,
        mean: d,
        median: d,
        p95: d,
        min: d,
    }
}

fn main() {
    let smoke = std::env::var("BITSMM_BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    bitsmm::bench_harness::header(
        "sim_cycle_accuracy",
        if smoke {
            "measured vs modelled cycles + driver fetch overlap (SMOKE mode)"
        } else {
            "measured simulator cycles vs the paper's analytic model (eq. 8 + readout), plus the driver's fetch/execute overlap"
        },
    );
    let cfg = if smoke {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            target_time: Duration::from_millis(50),
        }
    } else {
        BenchConfig::default()
    };
    let mut log: Vec<BenchResult> = Vec::new();

    // ---- 1. measured vs modelled cycles (eq. 8 + fill + readout) ------
    let mut t = Table::new(
        "measured vs modelled cycles (full-size tiles)",
        &["SA", "k", "bits", "measured", "eq8+fill+readout", "delta", "delta %"],
    );
    let topologies: &[(usize, usize)] = if smoke {
        &[(16, 4)]
    } else {
        &[(16, 4), (32, 8), (64, 16)]
    };
    let shapes: &[(usize, u32)] = if smoke {
        &[(32, 4), (128, 8)]
    } else {
        &[(32, 4), (128, 8), (512, 16)]
    };
    let mut worst_pct = 0.0f64;
    for &(cols, rows) in topologies {
        let sa = SaConfig::new(rows, cols, MacVariant::Booth);
        for &(k, bits) in shapes {
            let (m, n) = (rows, cols);
            let a = vec![3i32; m * k];
            let b = vec![-2i32; k * n];
            let mut arr = SystolicArray::new(sa);
            let out = arr.matmul(&a, &b, m, k, n, bits).expect("sim");
            let measured = out.stats.total_cycles();
            let modelled = tile_matmul(m, k, n, &sa).total_cycles(&sa, bits);
            let delta = measured as i64 - modelled as i64;
            let pct = delta.unsigned_abs() as f64 / modelled as f64 * 100.0;
            worst_pct = worst_pct.max(pct);
            t.row(&[
                sa.label(),
                k.to_string(),
                bits.to_string(),
                measured.to_string(),
                modelled.to_string(),
                delta.to_string(),
                f(pct),
            ]);
            assert!(pct < 5.0, "{} k={k} b={bits}: {pct}%", sa.label());
        }
    }
    print!("{}", t.render());
    println!(
        "worst model error: {}% (paper's eq. 9 ignores the systolic fill; the sim measures it)\n",
        f(worst_pct)
    );

    // ---- 2. driver fetch/execute overlap (before vs after) ------------
    // `serial` is what the pre-refactor accounting charged: every tile's
    // operand fetch on the critical path. `pipelined` is the streamed
    // driver's schedule, where tile N+1's DMA hides under tile N's
    // execute. Same instructions, same measured execute/writeback
    // cycles — the delta is purely the double-buffering win.
    let mut ot = Table::new(
        "driver schedule: serial (no overlap) vs pipelined (double-buffered fetch)",
        &["shape", "bits", "tiles", "fetch", "overlap", "stall", "serial", "pipelined", "saved %"],
    );
    let mut rng = Pcg32::new(0xc1cc);
    let driver_shapes: &[(usize, usize, usize, u32)] = if smoke {
        &[(8, 96, 48, 6)]
    } else {
        &[(8, 96, 48, 6), (16, 256, 64, 8), (12, 130, 40, 4)]
    };
    for &(m, k, n, bits) in driver_shapes {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let hi = (1i64 << (bits - 1)) as i32 - 1;
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(-hi, hi)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(-hi, hi)).collect();
        let (_, d) = device_matmul(sa, &a, &b, m, k, n, bits).expect("device matmul");
        assert!(d.tiles > 1, "{m}x{k}x{n} must tile on a 16x4 array");
        assert!(
            d.overlap_cycles > 0,
            "multi-tile shape {m}x{k}x{n} @{bits}b must overlap fetch with execute"
        );
        assert_eq!(d.fetch_cycles, d.overlap_cycles + d.stall_cycles);
        assert!(d.pipelined_cycles() <= d.serial_cycles());
        let saved =
            (d.serial_cycles() - d.pipelined_cycles()) as f64 / d.serial_cycles() as f64 * 100.0;
        let label = format!("{m}x{k}x{n}");
        ot.row(&[
            label.clone(),
            bits.to_string(),
            d.tiles.to_string(),
            d.fetch_cycles.to_string(),
            d.overlap_cycles.to_string(),
            d.stall_cycles.to_string(),
            d.serial_cycles().to_string(),
            d.pipelined_cycles().to_string(),
            f(saved),
        ]);
        log.push(cycle_row(&format!("driver {label} @{bits}b serial"), d.serial_cycles()));
        log.push(cycle_row(&format!("driver {label} @{bits}b pipelined"), d.pipelined_cycles()));
        log.push(cycle_row(&format!("driver {label} @{bits}b fetch_overlap"), d.overlap_cycles));
    }
    print!("{}", ot.render());
    println!(
        "(fetch == overlap + stall by construction; only the stall remainder reaches the pipelined critical path)\n"
    );

    // ---- 3. host-side simulator throughput (feeds the §Perf log) ------
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let (m, k, n, bits) = (4usize, 64usize, 16usize, 8u32);
    let a = vec![7i32; m * k];
    let b = vec![-7i32; k * n];
    let mut arr = SystolicArray::new(sa);
    let r = bench("simulate 4x64x16 @8b on 16x4", cfg, || {
        arr.matmul(&a, &b, m, k, n, bits).unwrap().stats.total_cycles()
    });
    println!("{}", r.format());
    let cycles = arr.matmul(&a, &b, m, k, n, bits).unwrap().stats.total_cycles();
    println!(
        "host rate: {} simulated cycles/s ({} cycles per call)",
        f(cycles as f64 / r.mean.as_secs_f64()),
        cycles
    );
    log.push(r);

    // driver on the same small shape, end to end (pack + stream + drain)
    let r = bench("device_matmul 4x64x16 @8b on 16x4", cfg, || {
        device_matmul(sa, &a, &b, m, k, n, bits).unwrap().1.hw_cycles()
    });
    println!("{}", r.format());
    log.push(r);

    match bitsmm::bench_harness::write_json("sim_cycle", &log) {
        Ok(path) => println!("\nwrote {path} ({} results)", log.len()),
        Err(e) => println!("\ncould not write bench json: {e}"),
    }
    println!("sim_cycle_accuracy bench OK");
}
