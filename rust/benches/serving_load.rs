//! Bench SERVING: the serving load harness (ROADMAP §Serving) — open-
//! and closed-loop arrival processes driven through the real
//! `InferenceServer`, with mixed model and precision traffic:
//!
//!   1. closed-loop: a fixed window of in-flight requests per model
//!      (the "saturated clients" regime — measures capacity),
//!   2. open-loop: Poisson arrivals at a fixed offered rate
//!      (the "independent users" regime — measures latency under load;
//!      open-loop numbers do not hide queueing the way closed-loop
//!      ones do),
//!   3. mixed precision: a burst of interleaved full-precision and
//!      low-priority traffic over the headroom zoo with the degrade
//!      policy armed, so part of the stream serves at the precision
//!      floor.
//!
//! Latency statistics come from the server's own bounded histogram
//! (`Metrics::latency`, DESIGN.md §Observability) — the same numbers a
//! production `--metrics-file` snapshot would report — and every
//! scenario lands in `BENCH_serving.json` at the repo root, like
//! `perf_hotpath` does, so the serving trajectory is machine-trackable
//! across PRs.
//!
//! Set `BITSMM_BENCH_SMOKE=1` (CI does) for a seconds-not-minutes run
//! that still produces the JSON artifact.

use bitsmm::bench_harness::BenchResult;
use bitsmm::coordinator::{
    Backend, BatcherConfig, DegradePolicy, InferenceServer, Metrics, Request, ServerConfig,
};
use bitsmm::nn::model::zoo_model;
use bitsmm::prng::Pcg32;
use bitsmm::report::f;
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let smoke = std::env::var("BITSMM_BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    bitsmm::bench_harness::header(
        "serving_load",
        if smoke {
            "open/closed-loop serving load (SMOKE mode: small request budgets)"
        } else {
            "open/closed-loop serving load through the inference server"
        },
    );
    let mut log: Vec<BenchResult> = Vec::new();

    // ---- 1. closed-loop: saturated clients per model --------------------
    let n_closed = if smoke { 24 } else { 256 };
    for model in ["mlp", "cnn"] {
        let m = run_closed_loop(model, n_closed, 8).unwrap();
        push_scenario(&mut log, &format!("closed-loop {model} w=8 n={n_closed}"), &m);
    }

    // ---- 2. open-loop: Poisson arrivals at fixed offered rates ----------
    // rates bracket the closed-loop capacity so the sweep shows the
    // latency knee; arrivals are submitted on schedule regardless of
    // completions (the defining property of open loop)
    let n_open = if smoke { 24 } else { 192 };
    let rates: &[f64] = if smoke { &[200.0, 1000.0] } else { &[200.0, 1000.0, 4000.0] };
    for &rate in rates {
        let m = run_open_loop("mlp", n_open, rate).unwrap();
        push_scenario(
            &mut log,
            &format!("open-loop mlp rate={rate}rps n={n_open}"),
            &m,
        );
    }

    // ---- 3. mixed precision: degrade under a low-priority burst ---------
    let n_mixed = if smoke { 24 } else { 128 };
    let m = run_mixed_precision(n_mixed).unwrap();
    push_scenario(
        &mut log,
        &format!("mixed-precision mlp-headroom burst n={n_mixed}"),
        &m,
    );
    println!(
        "  degraded serves in the mixed burst: {} of {}",
        m.degraded, m.requests
    );

    match bitsmm::bench_harness::write_json("serving", &log) {
        Ok(path) => println!("\nwrote {path} ({} results)", log.len()),
        Err(e) => println!("\ncould not write bench json: {e}"),
    }
    println!("serving_load bench OK");
}

/// Standard packed-backend server config for the harness.
fn harness_cfg() -> ServerConfig {
    let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
    cfg.workers = 2;
    cfg.batcher = BatcherConfig {
        max_batch: 8,
        linger: Duration::from_millis(1),
        ..BatcherConfig::default()
    };
    cfg
}

/// Closed loop: keep `window` requests in flight until `n` complete.
/// Measures capacity — each completion immediately triggers the next
/// submission, so the server never starves and never over-queues.
fn run_closed_loop(model: &str, n: usize, window: usize) -> bitsmm::Result<Metrics> {
    let model = Arc::new(zoo_model(model, 1)?);
    let inputs = bitsmm::coordinator::shaped_inputs(&model, n, 0x10ad);
    let server = InferenceServer::start(model, harness_cfg())?;
    let mut pending = std::collections::VecDeque::new();
    for (i, x) in inputs.into_iter().enumerate() {
        if pending.len() >= window {
            let rx: std::sync::mpsc::Receiver<_> = pending.pop_front().unwrap();
            rx.recv()?.output?;
        }
        pending.push_back(server.submit(Request::new(i as u64, x)));
    }
    for rx in pending {
        rx.recv()?.output?;
    }
    let (_, metrics) = server.shutdown();
    Ok(metrics)
}

/// Open loop: submit on a Poisson arrival schedule (exponential
/// inter-arrival gaps at `rate` req/s) regardless of completions, then
/// drain. Latency under load includes every queueing effect.
fn run_open_loop(model: &str, n: usize, rate: f64) -> bitsmm::Result<Metrics> {
    let model = Arc::new(zoo_model(model, 1)?);
    let inputs = bitsmm::coordinator::shaped_inputs(&model, n, 0xa661);
    let server = InferenceServer::start(model, harness_cfg())?;
    let mut rng = Pcg32::new(0x0907 + rate as u64);
    let mut rxs = Vec::with_capacity(n);
    let mut next_arrival = Instant::now();
    for (i, x) in inputs.into_iter().enumerate() {
        let now = Instant::now();
        if next_arrival > now {
            std::thread::sleep(next_arrival - now);
        }
        rxs.push(server.submit(Request::new(i as u64, x)));
        // u in (0, 1]: the +1 keeps ln() off exactly zero
        let u = (rng.next_u32() as f64 + 1.0) / (u32::MAX as f64 + 1.0);
        next_arrival += Duration::from_secs_f64(-u.ln() / rate);
    }
    for rx in rxs {
        rx.recv()?.output?;
    }
    let (_, metrics) = server.shutdown();
    Ok(metrics)
}

/// Mixed precision: an all-at-once burst over the headroom zoo with the
/// degrade policy armed — queue pressure pushes the low-priority half
/// of the stream down to the precision floor while the full-precision
/// half serves untouched (outputs stay per-request deterministic).
fn run_mixed_precision(n: usize) -> bitsmm::Result<Metrics> {
    let model = Arc::new(zoo_model("mlp-headroom", 1)?);
    let inputs = bitsmm::coordinator::shaped_inputs(&model, n, 0x3141);
    let mut cfg = harness_cfg();
    cfg.degrade = Some(DegradePolicy { high_water: 2, floor_bits: 4 });
    let server = InferenceServer::start(model, cfg)?;
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(i, x)| {
            let req = Request::new(i as u64, x);
            let req = if i % 2 == 1 { req.low_priority() } else { req };
            server.submit(req)
        })
        .collect();
    for rx in rxs {
        rx.recv()?.output?;
    }
    let (_, metrics) = server.shutdown();
    Ok(metrics)
}

/// Fold one scenario's server-side latency histogram into the bench
/// log (mean/p50/p95/min in the `BenchResult` slots) and print the
/// standard bench line plus throughput.
fn push_scenario(log: &mut Vec<BenchResult>, name: &str, m: &Metrics) {
    let p = m.latency.percentiles(&[50.0, 95.0]);
    let r = BenchResult {
        name: name.to_string(),
        iters: m.latency.count() as u64,
        mean: Duration::from_micros(m.latency.mean_us() as u64),
        median: Duration::from_micros(p[0]),
        p95: Duration::from_micros(p[1]),
        min: Duration::from_micros(m.latency.min_us()),
    };
    println!(
        "{}   ({} req/s, mean batch {})",
        r.format(),
        f(m.throughput_rps()),
        f(m.mean_batch())
    );
    log.push(r);
}
