//! Bench T4: regenerate paper Table IV (comparison with SOTA) and
//! verify the comparison's *shape*: who wins each metric, by roughly
//! the paper's factors.

use bitsmm::arch::asic::AsicModel;
use bitsmm::arch::fpga::FpgaModel;
use bitsmm::arch::pdk::PdkKind;
use bitsmm::baselines::{binary_ops_to_16b, table4_published, Bismo, Fssa, SerialDotModel};
use bitsmm::report::{f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;

fn main() {
    bitsmm::bench_harness::header("table4_sota", "paper Table IV: comparison with SOTA");
    print!("{}", bitsmm::report::paper::render_table4());

    let published = table4_published();
    let ours_fpga = FpgaModel::default().implement(SaConfig::new(16, 64, MacVariant::Booth), 16);
    let ours_asic = AsicModel::new(PdkKind::Asap7).implement(SaConfig::new(16, 64, MacVariant::Booth), 16);

    // --- shape assertions: the paper's own conclusions -----------------
    // (1) "optimized BISMO still provides higher throughput than bitSMM"
    assert!(published[0].gops_16b > ours_fpga.gops, "BISMO FPGA GOPS");
    assert!(published[0].gops_per_w > ours_fpga.gops_per_w);
    // (2) "bitSMM exhibits a higher throughput than FSSA"
    assert!(ours_asic.peak_gops_at_fmax > published[1].gops_16b);
    // (3) "the latter (FSSA) reports superior throughput per watt"
    assert!(published[1].gops_per_w > ours_asic.gops_per_w);
    // (4) area efficiency: ours 552 vs FSSA 40.86 GOPS/mm2 (~13.5×)
    let area_adv = ours_asic.gops_per_mm2 / published[1].gops_per_mm2.unwrap();
    assert!(
        (10.0..=18.0).contains(&area_adv),
        "area advantage {area_adv} out of the paper's ballpark (13.5x)"
    );
    println!("shape checks OK: BISMO>ours on FPGA GOPS; ours>FSSA GOPS; FSSA>ours GOPS/W; ours {}x FSSA GOPS/mm2", f(area_adv));

    // --- conversion convention check -----------------------------------
    assert_eq!(binary_ops_to_16b(256e9), 1e9);

    // --- cycle-model comparison on a common workload --------------------
    // dot product len 256 at 16/8/4/2 bits — the eq.6-family baselines
    // vs eq.8 (per-MAC latency, no spatial parallelism on either side)
    let mut t = Table::new(
        "per-MAC dot-product latency (cycles, len=256)",
        &["bits", "bitSMM (eq.8)", "BISMO serial (eq.6)", "BISMO opt (dk=64)", "FSSA", "Loom (g=16)"],
    );
    let bismo = Bismo::serial();
    let bismo_opt = Bismo::optimized();
    let fssa = Fssa::default();
    let loom = bitsmm::baselines::Loom::default();
    for bits in [2u32, 4, 8, 16] {
        let ours = bitsmm::arch::throughput::bitsmm_cycles(256, bits);
        t.row(&[
            bits.to_string(),
            ours.to_string(),
            bismo.dot_cycles(bits, bits, 256).to_string(),
            bismo_opt.dot_cycles(bits, bits, 256).to_string(),
            fssa.dot_cycles(bits, bits, 256).to_string(),
            loom.dot_cycles(bits, bits, 256).to_string(),
        ]);
        if bits > 2 {
            assert!(ours < bismo.dot_cycles(bits, bits, 256));
        }
    }
    print!("{}", t.render());
    println!("table4 bench OK");
}
