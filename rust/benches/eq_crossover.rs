//! Bench EQ68: the §III-A latency-scaling claim — eq. 8 (bitSMM,
//! linear in b_max) vs eq. 6 (BISMO/Loom decomposition, quadratic in
//! the bit widths): bitSMM wins whenever both operands exceed 1 bit,
//! ties at 2×2, loses when one operand is 1-bit (asymmetric widths are
//! BISMO's strength).

use bitsmm::arch::throughput::latency_pair;
use bitsmm::report::{ascii_plot, f, Table};

fn main() {
    bitsmm::bench_harness::header(
        "eq_crossover",
        "paper §III-A: eq. 8 vs eq. 6 latency scaling and crossover",
    );
    let n = 1024u64;

    // symmetric widths: ratio table
    let mut t = Table::new(
        &format!("symmetric operand widths (n = {n})"),
        &["bits", "bitSMM cycles", "eq.6 cycles", "speedup"],
    );
    let mut series = Vec::new();
    for b in 1..=16u32 {
        let (ours, theirs) = latency_pair(b, b, n);
        t.row(&[
            b.to_string(),
            ours.to_string(),
            theirs.to_string(),
            f(theirs as f64 / ours as f64),
        ]);
        series.push((b as f64, theirs as f64 / ours as f64));
    }
    print!("{}", t.render());
    print!(
        "{}",
        ascii_plot("speedup (eq.6 / eq.8) vs operand width", &[("speedup", &series)], 12)
    );

    // crossover structure
    let mut wins = 0;
    let mut losses = 0;
    let mut ties = 0;
    for b_mc in 1..=16u32 {
        for b_ml in 1..=16u32 {
            let (ours, theirs) = latency_pair(b_mc, b_ml, n);
            let r = ours as f64 / theirs as f64;
            if r < 0.999 {
                wins += 1;
            } else if r > 1.001 {
                losses += 1;
            } else {
                ties += 1;
            }
        }
    }
    println!("\nasymmetric sweep over (b_mc, b_ml) in 1..=16 x 1..=16, n={n}:");
    println!("  bitSMM faster: {wins}   slower: {losses}   ~tie: {ties}");

    // paper claims, asserted
    for b_mc in 2..=16u32 {
        for b_ml in 2..=16u32 {
            if b_mc == 2 && b_ml == 2 {
                continue;
            }
            let (ours, theirs) = latency_pair(b_mc, b_ml, n);
            assert!(ours < theirs, "({b_mc},{b_ml})");
        }
    }
    // the paper's "matches prior approaches only when b_mc=b_ml=2"
    // reads per single multiplication (n = 1): (1+1)·2 = 2·2·1 = 4.
    // Over a vector, eq. 8 amortizes its +1 slot and wins even at 2×2.
    let (t22_ours, t22_theirs) = latency_pair(2, 2, 1);
    assert_eq!(t22_ours, t22_theirs, "2x2 tie at n=1");
    let (t22v_ours, t22v_theirs) = latency_pair(2, 2, n);
    assert!(t22v_ours < t22v_theirs, "2x2 vector case amortizes the lead-in");
    let (o1, t1) = latency_pair(1, 16, n);
    assert!(o1 > t1, "1-bit asymmetric case favours eq.6");
    println!("crossover assertions OK (wins for all b>1 pairs; exact 2x2 tie at n=1)");
}
