//! Bench ABL: the MAC-variant ablation (Booth vs SBMwC) the paper runs
//! at 16×4 — resources (Table II/III rows), switching activity
//! (measured on the cycle-accurate sim), and the resulting GOPS/W
//! ordering. DESIGN.md calls this the central design choice.

use bitsmm::arch::asic::AsicModel;
use bitsmm::arch::fpga::FpgaModel;
use bitsmm::arch::pdk::PdkKind;
use bitsmm::prng::Pcg32;
use bitsmm::report::{f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::driver::mac_dot_with_stats;
use bitsmm::sim::mac_common::MacVariant;

fn main() {
    bitsmm::bench_harness::header("ablation_mac", "Booth vs SBMwC: resources, activity, efficiency");

    // --- switching activity measured on random data --------------------
    let mut rng = Pcg32::new(0xab1a);
    let mut t = Table::new(
        "measured adder activity (random operands, len 512)",
        &["bits", "booth adder ops", "sbmwc adder ops", "ratio", "booth duty", "sbmwc duty"],
    );
    for bits in [4u32, 8, 16] {
        let lo = bitsmm::bits::twos::min_value(bits);
        let hi = bitsmm::bits::twos::max_value(bits);
        let mc: Vec<i32> = (0..512).map(|_| rng.range_i32(lo, hi)).collect();
        let ml: Vec<i32> = (0..512).map(|_| rng.range_i32(lo, hi)).collect();
        let booth = mac_dot_with_stats(MacVariant::Booth, &mc, &ml, bits, 48);
        let sbmwc = mac_dot_with_stats(MacVariant::Sbmwc, &mc, &ml, bits, 48);
        assert_eq!(booth.0, sbmwc.0, "variants must agree numerically");
        let ratio = sbmwc.2.adder_ops as f64 / booth.2.adder_ops as f64;
        t.row(&[
            bits.to_string(),
            booth.2.adder_ops.to_string(),
            sbmwc.2.adder_ops.to_string(),
            f(ratio),
            f(booth.2.adder_duty()),
            f(sbmwc.2.adder_duty()),
        ]);
        assert!(ratio > 1.5, "SBMwC must fire substantially more adders");
    }
    print!("{}", t.render());

    // --- implementation cost at 16×4 (the paper's ablation point) ------
    let fpga = FpgaModel::default();
    let booth = fpga.implement(SaConfig::new(4, 16, MacVariant::Booth), 16);
    let sbmwc = fpga.implement(SaConfig::new(4, 16, MacVariant::Sbmwc), 16);
    let mut t = Table::new(
        "implementation cost (16x4, modelled)",
        &["metric", "booth", "sbmwc", "sbmwc/booth"],
    );
    t.row(&["FPGA LUTs".into(), booth.luts.to_string(), sbmwc.luts.to_string(), f(sbmwc.luts as f64 / booth.luts as f64)]);
    t.row(&["FPGA FFs".into(), booth.ffs.to_string(), sbmwc.ffs.to_string(), f(sbmwc.ffs as f64 / booth.ffs as f64)]);
    t.row(&["FPGA power (W)".into(), f(booth.power_w), f(sbmwc.power_w), f(sbmwc.power_w / booth.power_w)]);
    t.row(&["FPGA GOPS/W".into(), f(booth.gops_per_w), f(sbmwc.gops_per_w), f(sbmwc.gops_per_w / booth.gops_per_w)]);
    for kind in [PdkKind::Asap7, PdkKind::Nangate45] {
        let asic = AsicModel::new(kind);
        let b = asic.implement(SaConfig::new(4, 16, MacVariant::Booth), 16);
        let s = asic.implement(SaConfig::new(4, 16, MacVariant::Sbmwc), 16);
        t.row(&[
            format!("{} area (mm2)", kind.name()),
            format!("{:.4}", b.area_mm2),
            format!("{:.4}", s.area_mm2),
            f(s.area_mm2 / b.area_mm2),
        ]);
        t.row(&[
            format!("{} GOPS/W", kind.name()),
            f(b.gops_per_w),
            f(s.gops_per_w),
            f(s.gops_per_w / b.gops_per_w),
        ]);
        assert!(b.gops_per_w > s.gops_per_w);
    }
    print!("{}", t.render());
    assert!(booth.gops_per_w > sbmwc.gops_per_w);
    println!("ablation OK: Booth dominates on resources and GOPS/W (the paper's default choice)");
}
