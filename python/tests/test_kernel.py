"""L1 Pallas kernel vs the pure-jnp oracle — the CORE correctness
signal of the Python layer (kernel ≙ RTL, ref ≙ testbench, SIV-A)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bitserial_matmul import bitserial_matmul, vmem_bytes_estimate


def rand_ops(seed, m, k, n, bits):
    rng = np.random.default_rng(seed)
    lo, hi = ref.min_value(bits), ref.max_value(bits)
    a = rng.integers(lo, hi + 1, size=(m, k), dtype=np.int32)
    b = rng.integers(lo, hi + 1, size=(k, n), dtype=np.int32)
    return jnp.asarray(a), jnp.asarray(b)


@pytest.mark.parametrize("variant", ["booth", "sbmwc"])
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
def test_kernel_matches_oracle_f32_regime(variant, bits):
    # serving regime: ≤8-bit operands — f32 accumulation is exact
    a, b = rand_ops(bits, 8, 64, 32, bits)
    got = bitserial_matmul(a, b, bits=bits, variant=variant)
    want = ref.matmul_exact(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("variant", ["booth", "sbmwc"])
def test_kernel_wide_precision_exact_in_f64(variant):
    a, b = rand_ops(7, 4, 32, 8, 16)
    got = bitserial_matmul(a, b, bits=16, variant=variant, acc_dtype=jnp.float64)
    want = ref.matmul_exact(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_paper_eq5_values():
    # 6 × (−2) = −12 at 4 bits (paper eq. 2/5)
    a = jnp.array([[-2]], dtype=jnp.int32)  # multiplier
    b = jnp.array([[6]], dtype=jnp.int32)  # multiplicand
    for variant in ["booth", "sbmwc"]:
        out = bitserial_matmul(a, b, bits=4, variant=variant)
        assert int(out[0, 0]) == -12


def test_tiling_covers_non_divisible_shapes():
    a, b = rand_ops(3, 130, 70, 65, 4)
    got = bitserial_matmul(a, b, bits=4, variant="booth", tile_m=64, tile_n=64)
    want = ref.matmul_exact(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_rejects_bad_args():
    a = jnp.zeros((2, 3), jnp.int32)
    b = jnp.zeros((3, 2), jnp.int32)
    with pytest.raises(ValueError):
        bitserial_matmul(a, b, bits=0)
    with pytest.raises(ValueError):
        bitserial_matmul(a, b, bits=17)
    with pytest.raises(ValueError):
        bitserial_matmul(a, jnp.zeros((4, 2), jnp.int32), bits=4)


@given(
    variant=st.sampled_from(["booth", "sbmwc"]),
    bits=st.integers(1, 8),
    m=st.integers(1, 9),
    k=st.integers(1, 17),
    n=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_kernel_property_sweep(variant, bits, m, k, n, seed):
    """Hypothesis sweep over shapes/precisions/variants (SIV-A's random
    testbench axis, Python side)."""
    a, b = rand_ops(seed, m, k, n, bits)
    got = bitserial_matmul(a, b, bits=bits, variant=variant)
    want = ref.matmul_exact(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_precision_is_a_schedule_knob():
    """Same operands, reduced precision: result equals matmul of the
    values *wrapped* to the narrower width — precision trades accuracy,
    mirroring the hardware's runtime-configurable width."""
    a = jnp.array([[5]], dtype=jnp.int32)  # 0101
    b = jnp.array([[1]], dtype=jnp.int32)
    # at 3 bits the pattern 101 reads as −3
    out = bitserial_matmul(a, b, bits=3, variant="booth")
    assert int(out[0, 0]) == -3


def test_vmem_estimate_monotone():
    small = vmem_bytes_estimate(32, 64, 32)
    big = vmem_bytes_estimate(128, 64, 128)
    assert big > small > 0
