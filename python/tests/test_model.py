"""L2 model tests: shapes, quantization, per-layer precision, and the
attention block — everything aot.py exports."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def test_quantize_roundtrip_range():
    x = jnp.linspace(-1.0, 1.0, 101)
    q = model.quantize(x, scale=1 / 127, bits=8)
    assert int(jnp.min(q)) >= -128 and int(jnp.max(q)) <= 127
    # dequantized error bounded by half a step
    err = jnp.max(jnp.abs(q * (1 / 127) - x))
    assert float(err) <= 0.5 / 127 + 1e-6


def test_quantize_clamps_saturating():
    x = jnp.array([10.0, -10.0])
    q = model.quantize(x, scale=1 / 127, bits=8)
    assert q.tolist() == [127, -128]


def test_linear_layer_matches_dense_reference():
    key = jax.random.PRNGKey(1)
    x = jax.random.randint(key, (4, 16), -8, 8, jnp.int32)
    w = jax.random.randint(key, (16, 8), -8, 8, jnp.int32)
    b = jax.random.randint(key, (8,), -8, 8, jnp.int32)
    out = model.linear_bitserial(x, w, b, bits=4)
    want = np.asarray(ref.matmul_exact(x, w)) + np.asarray(b)
    np.testing.assert_allclose(np.asarray(out), want, rtol=0, atol=0)


@pytest.mark.parametrize("batch", [1, 8])
def test_mlp_forward_shapes_and_finiteness(batch):
    dims = [64, 64, 32, 10]
    bits = [8, 4, 4]
    key = jax.random.PRNGKey(0)
    ws, bs = model.make_mlp_params(key, dims, layer_bits=bits)
    x = jax.random.randint(key, (batch, dims[0]), -128, 128, jnp.int32)
    out = model.mlp_forward(x, ws, bs, layer_bits=bits, scales=[0.05, 0.1, 0.2])
    assert out.shape == (batch, 10)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_mlp_per_layer_precision_changes_output():
    """The per-layer bit-width knob must actually matter."""
    dims = [16, 16, 8]
    key = jax.random.PRNGKey(3)
    ws, bs = model.make_mlp_params(key, dims, layer_bits=[8, 8])
    x = jax.random.randint(key, (4, 16), -100, 100, jnp.int32)
    hi = model.mlp_forward(x, ws, bs, layer_bits=[8, 8], scales=[0.1, 0.1])
    # clamp weights into the 3-bit grid for the low-precision run so
    # both runs are over in-range operands
    ws3 = [jnp.clip(w, -4, 3) for w in ws]
    x3 = jnp.clip(x, -4, 3)
    lo = model.mlp_forward(x3, ws3, bs, layer_bits=[3, 3], scales=[0.1, 0.1])
    assert not np.allclose(np.asarray(hi), np.asarray(lo))


def test_attention_block_shapes():
    key = jax.random.PRNGKey(5)
    seq, dim = 8, 16
    x = jax.random.randint(key, (seq, dim), -64, 64, jnp.int32)
    wq, wk, wv, wo = (
        jax.random.randint(jax.random.fold_in(key, i), (dim, dim), -64, 64, jnp.int32)
        for i in range(4)
    )
    out = model.attention_forward(x, wq, wk, wv, wo, bits=8)
    assert out.shape == (seq, dim)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_attention_softmax_rows_stochastic():
    """Indirect check that the attention path normalizes: output is a
    convex combination of V projections, so it is bounded by V's row
    extremes (up to the output projection)."""
    key = jax.random.PRNGKey(6)
    seq, dim = 4, 8
    x = jax.random.randint(key, (seq, dim), -8, 8, jnp.int32)
    eye = jnp.eye(dim, dtype=jnp.int32)
    out = model.attention_forward(x, eye, eye, eye, eye, bits=8)
    v = ref.matmul_exact(x, eye).astype(jnp.float64)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1.0
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1.0
