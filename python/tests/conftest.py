"""Shared pytest config: enable x64 so the exact (f64/int64) oracle
paths behave identically to the AOT export environment."""

import jax

jax.config.update("jax_enable_x64", True)
