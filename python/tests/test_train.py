"""Trained-model export: the bit-serial-served accuracy must be close
to float accuracy and well above chance (10 classes)."""

import os

import pytest

from compile import train


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    out = tmp_path_factory.mktemp("trained")
    return train.export_trained(str(out), seed=0), out


def test_float_accuracy_trains(trained):
    info, _ = trained
    assert info["float_acc"] > 0.9, info


def test_bitserial_accuracy_close_to_float(trained):
    info, _ = trained
    assert info["quant_acc"] > 0.85, info
    assert info["float_acc"] - info["quant_acc"] < 0.08, info


def test_export_file_structure(trained):
    info, _ = trained
    with open(info["path"]) as f:
        text = f.read()
    assert "layers 3" in text
    assert text.count("layer ") == 3
    assert "eval 400 64" in text
    # one weight blob and one bias blob per layer
    assert sum(1 for l in text.splitlines() if l.startswith("w ")) == 3
    assert sum(1 for l in text.splitlines() if l.startswith("b ")) == 3
    # weight blob sizes match the declared dims
    for line in text.splitlines():
        if line.startswith("layer 0"):
            assert " in 64 out 64 bits 8 " in line


def test_weights_in_declared_range(trained):
    info, _ = trained
    bits = iter(train.LAYER_BITS)
    with open(info["path"]) as f:
        lines = f.readlines()
    for i, line in enumerate(lines):
        if line.startswith("layer "):
            b = int(line.split(" bits ")[1].split()[0])
            w = [int(v) for v in lines[i + 1].split()[1:]]
            from compile.kernels import ref

            assert min(w) >= ref.min_value(b)
            assert max(w) <= ref.max_value(b)
            next(bits)
