"""Oracle self-consistency: the plane decompositions reconstruct plain
integer matmul — mirroring rust/src/bits tests (shared ground truth)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def rand_ops(seed, m, k, n, bits):
    rng = np.random.default_rng(seed)
    lo, hi = ref.min_value(bits), ref.max_value(bits)
    a = rng.integers(lo, hi + 1, size=(m, k), dtype=np.int32)
    b = rng.integers(lo, hi + 1, size=(k, n), dtype=np.int32)
    return jnp.asarray(a), jnp.asarray(b)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8, 12, 16])
def test_booth_planes_reconstruct(bits):
    a, b = rand_ops(bits, 5, 7, 3, bits)
    got = ref.booth_plane_matmul(a, b, bits)
    want = ref.matmul_exact(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8, 12, 16])
def test_sbmwc_planes_reconstruct(bits):
    a, b = rand_ops(100 + bits, 5, 7, 3, bits)
    got = ref.sbmwc_plane_matmul(a, b, bits)
    want = ref.matmul_exact(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_booth_digits_table1():
    # 0110 = 6 → digits [0,-1,0,+1] (paper eq. 4/5)
    a = jnp.array([[6]], dtype=jnp.int32)
    digits = [int(ref.booth_digit_plane(a, i)[0, 0]) for i in range(4)]
    assert digits == [0, -1, 0, 1]
    # 1110 = −2 → [0,-1,0,0]
    a = jnp.array([[-2]], dtype=jnp.int32)
    digits = [int(ref.booth_digit_plane(a, i)[0, 0]) for i in range(4)]
    assert digits == [0, -1, 0, 0]


@given(
    bits=st.integers(1, 16),
    m=st.integers(1, 6),
    k=st.integers(1, 12),
    n=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_plane_identities_property(bits, m, k, n, seed):
    a, b = rand_ops(seed, m, k, n, bits)
    want = np.asarray(ref.matmul_exact(a, b))
    np.testing.assert_array_equal(np.asarray(ref.booth_plane_matmul(a, b, bits)), want)
    np.testing.assert_array_equal(np.asarray(ref.sbmwc_plane_matmul(a, b, bits)), want)


def test_check_range_rejects():
    with pytest.raises(ValueError):
        ref.check_range(jnp.array([128], dtype=jnp.int32), 8)
    with pytest.raises(ValueError):
        ref.check_range(jnp.array([-129], dtype=jnp.int32), 8)
    ref.check_range(jnp.array([-128, 127], dtype=jnp.int32), 8)
