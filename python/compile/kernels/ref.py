"""Pure-jnp correctness oracles for the bit-serial matmul kernel.

This is the Python ground truth mirroring ``rust/src/bits``: integer
matmul, two's-complement ranges, Booth signed-digit planes (paper
Table I) and SBMwC bit planes (paper eq. 2). The Pallas kernel
(``bitserial_matmul.py``) is tested against these by pytest/hypothesis,
exactly as the paper validates its RTL against reference testbenches
(SIV-A).
"""

from __future__ import annotations

import jax.numpy as jnp

MAX_BITS = 16


def min_value(bits: int) -> int:
    """Smallest value representable in ``bits``-bit two's complement."""
    return -(1 << (bits - 1))


def max_value(bits: int) -> int:
    """Largest value representable in ``bits``-bit two's complement."""
    return (1 << (bits - 1)) - 1


def check_range(x, bits: int) -> None:
    """Raise if any element of ``x`` falls outside the operand range."""
    lo, hi = min_value(bits), max_value(bits)
    xmin, xmax = int(jnp.min(x)), int(jnp.max(x))
    if xmin < lo or xmax > hi:
        raise ValueError(
            f"operand out of {bits}-bit two's-complement range: "
            f"[{xmin}, {xmax}] vs [{lo}, {hi}]"
        )


def matmul_exact(a, b):
    """Plain integer matmul in 64-bit — the numeric reference."""
    return jnp.matmul(a.astype(jnp.int64), b.astype(jnp.int64))


def booth_digit_plane(a, i: int):
    """Booth signed digit ``d_i = ml[i-1] − ml[i]`` of each element
    (paper Table I), values in {−1, 0, +1}."""
    cur = (a >> i) & 1
    prev = (a >> (i - 1)) & 1 if i > 0 else jnp.zeros_like(a)
    return prev - cur


def sbmwc_bit_plane(a, i: int):
    """Raw bit plane ``i`` (values in {0, 1})."""
    return (a >> i) & 1


def booth_plane_matmul(a, b, bits: int):
    """``A·B`` via Booth planes of the multiplier A:
    ``Σ_i 2^i · (D_i(A) · B)`` — the identity the hardware MAC realises
    one bit per *cycle* and the Pallas kernel realises one plane per
    *grid step*. Exact (int64)."""
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.int64)
    b64 = b.astype(jnp.int64)
    for i in range(bits):
        d = booth_digit_plane(a, i).astype(jnp.int64)
        acc = acc + ((d @ b64) << i)
    return acc


def sbmwc_plane_matmul(a, b, bits: int):
    """``A·B`` via raw bit planes with the sign-bit correction (paper
    eq. 2): ``Σ_{i<b−1} 2^i·(P_i·B) − 2^{b−1}·(P_{b−1}·B)``."""
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.int64)
    b64 = b.astype(jnp.int64)
    for i in range(bits):
        p = sbmwc_bit_plane(a, i).astype(jnp.int64)
        term = (p @ b64) << i
        acc = acc - term if i == bits - 1 else acc + term
    return acc
