"""L1 Pallas kernel: bit-plane (bit-serial-equivalent) matrix multiply.

TPU re-thinking of the paper's bit-serial MAC (DESIGN.md
SHardware-Adaptation): instead of streaming one bit per *cycle* into a
1-bit datapath, we stream one Booth-recoded bit-*plane* per grid step
into the MXU. The decomposition is identical to the hardware's:

* **booth**  — signed-digit planes ``d_i = ml[i-1] − ml[i]`` (Table I),
  every plane weighted ``+2^i``; no sign correction (the property that
  lets the hardware MAC use a single adder).
* **sbmwc**  — raw bit planes, the MSb plane weighted ``−2^(b−1)``
  (the correction step of eq. 2; the hardware variant that costs a
  second adder).

The multiplicand operand ``b`` participates at full precision, exactly
as in the hardware: the paper's MAC assembles the serial multiplicand
back to parallel form (multiplicand mask circuit) before the adder —
bit-seriality of the multiplicand is transport, not arithmetic.

Runtime-configurable precision — the paper's headline feature — maps to
the ``bits`` static argument: it sets the number of planes (grid
steps), so cycles scale linearly with precision just like eq. 8.

The kernel is written for MXU-friendly shapes (tiles of 128 in the
matmul dimensions; plane values in {−1,0,+1} are exactly representable
in bf16) but runs here under ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls, so correctness is validated on CPU
and TPU efficiency is estimated analytically (DESIGN.md SPerf/L1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default VMEM tile extents. 128 matches the MXU systolic array edge;
# tiles are clamped to the (padded) problem size.
TILE_M = 128
TILE_N = 128


def _plane(a, i: int, bits: int, variant: str):
    """Extract plane ``i`` and its scale factor. ``a`` is int32."""
    if variant == "booth":
        return ref.booth_digit_plane(a, i), float(2 ** i)
    if variant == "sbmwc":
        scale = -float(2 ** i) if i == bits - 1 else float(2 ** i)
        return ref.sbmwc_bit_plane(a, i), scale
    raise ValueError(f"unknown variant {variant!r}")


def _kernel(a_ref, b_ref, o_ref, *, bits: int, variant: str, acc_dtype):
    """One (tile_m × tile_n) output tile.

    The plane loop is the temporal dimension of the hardware (one bit
    per cycle ↔ one plane per iteration); the `plane @ b` contraction is
    the spatial dimension (the whole MAC grid at once). The VMEM
    accumulator plays the role of the per-MAC accumulator registers.

    Bit extraction is strength-reduced across iterations (SPerf/L2):
    plane i's `cur` bit is plane i+1's `prev`, so each iteration
    extracts exactly one fresh bit — halving the traced shift/and ops
    vs recomputing both (XLA would CSE them, but the smaller StableHLO
    lowers and compiles faster and keeps the artifact compact).
    """
    a = a_ref[...]  # [tm, K] int32 (multiplier / activations)
    b = b_ref[...].astype(acc_dtype)  # [K, tn] (multiplicand / weights)
    acc = jnp.zeros((a.shape[0], b.shape[1]), acc_dtype)
    prev = jnp.zeros_like(a)  # ml[-1] = 0 (Table I)
    for i in range(bits):  # static unroll: `bits` plane-matmuls
        cur = (a >> i) & 1
        if variant == "booth":
            plane, scale = prev - cur, float(2 ** i)
        elif variant == "sbmwc":
            scale = -float(2 ** i) if i == bits - 1 else float(2 ** i)
            plane = cur
        else:
            raise ValueError(f"unknown variant {variant!r}")
        acc = acc + jnp.matmul(plane.astype(acc_dtype), b) * acc_dtype(scale)
        prev = cur
    o_ref[...] = acc


def _pad_to(x, rows: int, cols: int):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(
    jax.jit, static_argnames=("bits", "variant", "acc_dtype", "tile_m", "tile_n")
)
def bitserial_matmul(
    a,
    b,
    *,
    bits: int = 8,
    variant: str = "booth",
    acc_dtype=jnp.float32,
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
):
    """Bit-serial-equivalent matmul ``A (m×k) · B (k×n)``.

    Args:
      a: int32 multiplier matrix (activations), values in the
         ``bits``-bit two's-complement range.
      b: int32 multiplicand matrix (weights), same range.
      bits: runtime-configured operand precision, 1..16 (static under
         jit — each precision is its own compiled executable, matching
         the hardware where precision reconfigures the *schedule*).
      variant: "booth" or "sbmwc" — which MAC architecture to mirror.
      acc_dtype: accumulator element type. f32 is exact for the serving
         regime (≤8-bit operands, k ≤ 1024 — every intermediate is an
         integer below 2^24); use f64 for exactness at 16-bit operands.

    Returns:
      The product, in ``acc_dtype``, shape (m, n).
    """
    if not 1 <= bits <= ref.MAX_BITS:
        raise ValueError(f"bits must be in 1..{ref.MAX_BITS}, got {bits}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    # pad M/N up to tile multiples (K stays whole: the contraction is
    # done per tile, mirroring one full dot product per MAC)
    tm = min(tile_m, m)
    tn = min(tile_n, n)
    mp = (m + tm - 1) // tm * tm
    np_ = (n + tn - 1) // tn * tn
    a_p = _pad_to(a, mp, k)
    b_p = _pad_to(b, k, np_)

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, variant=variant, acc_dtype=acc_dtype),
        grid=(mp // tm, np_ // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), acc_dtype),
        interpret=True,  # CPU path; real-TPU lowering emits Mosaic
    )(a_p, b_p)
    return out[:m, :n]


def vmem_bytes_estimate(tile_m: int, k: int, tile_n: int, acc_dtype=jnp.float32) -> int:
    """Per-grid-step VMEM footprint estimate for DESIGN.md SPerf/L1:
    A tile (int32) + B tile (acc) + accumulator (acc) + one plane (acc).
    """
    it = jnp.dtype(jnp.int32).itemsize
    at = jnp.dtype(acc_dtype).itemsize
    return tile_m * k * it + k * tile_n * at + 2 * tile_m * tile_n * at + tile_m * k * at
