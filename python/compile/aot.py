"""AOT export: lower the L2/L1 graphs to HLO **text** artifacts.

Python runs once (``make artifacts``); the Rust binary loads these
files through the PJRT CPU client and is self-contained afterwards.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and DESIGN.md.

Artifact inventory (written to ``artifacts/`` with ``manifest.txt``):
  * ``mm_<variant>_b<bits>_<m>x<k>x<n>[_exact]`` — bare bit-serial
    matmuls for the tile/layer shapes the coordinator serves.
  * ``mlp_<batch>`` — the quantized 3-layer MLP forward (per-layer
    precisions baked in) used by the e2e serving example.
  * ``attn_<seq>x<dim>`` — the attention block forward.

Manifest line format (parsed by ``rust/src/runtime/artifact.rs``):
  ``name kind variant bits m k n dtype path``
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

jax.config.update("jax_enable_x64", True)  # f64 accumulator variants


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def i32_spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# The matmul shapes the serving stack uses. (m, k, n) with m = batch
# rows per PJRT call; k/n = layer dims of the model zoo (kept small so
# `make artifacts` stays fast; the coordinator falls back to the native
# plane-matmul path for unlisted shapes).
MM_SHAPES = [
    (8, 64, 64),
    (8, 64, 32),
    (8, 32, 10),
    (32, 64, 64),
    (32, 64, 32),
    (32, 32, 10),
    (64, 128, 128),
]
MM_BITS = [2, 4, 8]
MM_VARIANTS = ["booth", "sbmwc"]

# MLP export: 64 → 64 → 32 → 10 with per-layer precisions 8/4/4 — the
# per-layer bit-width flexibility the paper's conclusion highlights.
MLP_DIMS = [64, 64, 32, 10]
MLP_BITS = [8, 4, 4]
MLP_BATCHES = [8, 32]

ATTN_SEQ, ATTN_DIM, ATTN_BITS = 16, 32, 8


def export(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def emit(name, kind, variant, bits, m, k, n, dtype, lowered):
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        manifest.append(f"{name} {kind} {variant} {bits} {m} {k} {n} {dtype} {path}")
        print(f"  wrote {path} ({len(text)} chars)")

    # ---- bare matmul executables -------------------------------------
    for variant in MM_VARIANTS:
        for bits in MM_BITS:
            for (m, k, n) in MM_SHAPES:
                fn = functools.partial(model.matmul_entry, bits=bits, variant=variant)
                low = jax.jit(fn).lower(i32_spec(m, k), i32_spec(k, n))
                emit(
                    f"mm_{variant}_b{bits}_{m}x{k}x{n}",
                    "matmul",
                    variant,
                    bits,
                    m,
                    k,
                    n,
                    "f32",
                    low,
                )
    # one exact (f64) wide-precision executable for cross-validation
    fn = functools.partial(model.matmul_entry_exact, bits=16, variant="booth")
    low = jax.jit(fn).lower(i32_spec(8, 64, ), i32_spec(64, 64))
    emit("mm_booth_b16_8x64x64_exact", "matmul", "booth", 16, 8, 64, 64, "f64", low)

    # ---- MLP forward ---------------------------------------------------
    key = jax.random.PRNGKey(0)
    ws, bs = model.make_mlp_params(key, MLP_DIMS, layer_bits=MLP_BITS)
    scales = [0.05, 0.1, 0.2]

    for batch in MLP_BATCHES:
        def mlp(x_q, *params):
            w = list(params[: len(ws)])
            b = list(params[len(ws):])
            return (
                model.mlp_forward(
                    x_q, w, b, layer_bits=MLP_BITS, scales=scales, variant="booth"
                ),
            )

        specs = [i32_spec(batch, MLP_DIMS[0])]
        specs += [i32_spec(*w.shape) for w in ws]
        specs += [i32_spec(*b.shape) for b in bs]
        low = jax.jit(mlp).lower(*specs)
        emit(
            f"mlp_{batch}",
            "mlp",
            "booth",
            MLP_BITS[0],
            batch,
            MLP_DIMS[0],
            MLP_DIMS[-1],
            "f32",
            low,
        )

    # ---- attention block -----------------------------------------------
    def attn(x_q, wq, wk, wv, wo):
        return (
            model.attention_forward(x_q, wq, wk, wv, wo, bits=ATTN_BITS, variant="booth"),
        )

    low = jax.jit(attn).lower(
        i32_spec(ATTN_SEQ, ATTN_DIM), *([i32_spec(ATTN_DIM, ATTN_DIM)] * 4)
    )
    emit(
        f"attn_{ATTN_SEQ}x{ATTN_DIM}",
        "attention",
        "booth",
        ATTN_BITS,
        ATTN_SEQ,
        ATTN_DIM,
        ATTN_DIM,
        "f32",
        low,
    )

    # ---- trained model (weights + eval set for the Rust stack) --------
    from . import train

    train.export_trained(out_dir)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts (+ trained_mlp.txt)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    export(args.out)


if __name__ == "__main__":
    main()
