"""Train a small MLP classifier and export it, quantized, for the Rust
serving stack — the "real small workload" of the end-to-end driver.

Workload: synthetic multi-class instrument-vector classification (the
in-situ data-analysis use case of the paper's introduction): 10
Gaussian class prototypes in 64 dimensions with additive noise. A
64-64-32-10 MLP is trained in float (plain JAX autodiff + SGD), then
post-training-quantized to the paper-style per-layer widths 8/4/4 and
*evaluated through the bit-serial kernel* so the exported accuracy is
the accuracy the accelerator actually delivers.

Export format (``artifacts/trained_mlp.txt``): a line-oriented
key/value + integer-blob format parsed by ``rust/src/nn/weights_io.rs``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.bitserial_matmul import bitserial_matmul

DIMS = [64, 64, 32, 10]
LAYER_BITS = [8, 4, 4]
N_CLASSES = 10
N_TRAIN, N_EVAL = 2000, 400
STEPS, LR, BATCH = 300, 0.05, 128


def make_prototypes(key):
    """The class definitions — shared between train and eval splits."""
    return jax.random.normal(key, (N_CLASSES, DIMS[0]))


def make_dataset(key, protos, n):
    """Samples around the given Gaussian class prototypes."""
    kx, ky = jax.random.split(key)
    y = jax.random.randint(ky, (n,), 0, N_CLASSES)
    x = protos[y] + 0.35 * jax.random.normal(kx, (n, DIMS[0]))
    return x, y


def init_params(key):
    params = []
    for i, (d_in, d_out) in enumerate(zip(DIMS[:-1], DIMS[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (d_in, d_out)) * (2.0 / d_in) ** 0.5
        params.append((w, jnp.zeros((d_out,))))
    return params


def forward_float(params, x):
    h = x
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def loss_fn(params, x, y):
    logits = forward_float(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


@jax.jit
def sgd_step(params, x, y):
    grads = jax.grad(loss_fn)(params, x, y)
    return [(w - LR * gw, b - LR * gb) for (w, b), (gw, gb) in zip(params, grads)]


def train(seed: int = 0):
    key = jax.random.PRNGKey(seed)
    kp, kd, ke, ki = jax.random.split(key, 4)
    protos = make_prototypes(kp)
    x_train, y_train = make_dataset(kd, protos, N_TRAIN)
    x_eval, y_eval = make_dataset(ke, protos, N_EVAL)
    params = init_params(ki)
    rng = np.random.default_rng(seed)
    for _ in range(STEPS):
        idx = rng.integers(0, N_TRAIN, BATCH)
        params = sgd_step(params, x_train[idx], y_train[idx])
    return params, (x_eval, y_eval)


def quantize_sym(x, bits):
    """Symmetric quantization; returns (q_int32, scale)."""
    amax = float(jnp.max(jnp.abs(x)))
    denom = max(ref.max_value(bits), 1)
    scale = amax / denom if amax > 0 else 1.0
    q = jnp.clip(jnp.round(x / scale), ref.min_value(bits), ref.max_value(bits))
    return q.astype(jnp.int32), scale


def forward_bitserial(qparams, scales, x_q, in_scale):
    """Quantized forward exactly as the Rust LinearLayer computes it:
    integer matmul on the bit-serial kernel, bias in accumulator units,
    ReLU in reals, requantize onto the next activation grid."""
    h_q, h_scale = x_q, in_scale
    n_layers = len(qparams)
    for i, (w_q, w_scale, b_acc) in enumerate(qparams):
        acc = bitserial_matmul(h_q, w_q, bits=LAYER_BITS[i], variant="booth")
        acc = acc + jnp.asarray(b_acc, acc.dtype)
        real = acc * (h_scale * w_scale)
        if i + 1 < n_layers:
            real = jax.nn.relu(real)
            out_bits = LAYER_BITS[i + 1]
            h_q, h_scale = quantize_sym(real, out_bits)
        else:
            return real
    raise AssertionError("unreachable")


def export_trained(out_dir: str, seed: int = 0) -> dict:
    params, (x_eval, y_eval) = train(seed)

    # float accuracy
    float_acc = float(
        jnp.mean(jnp.argmax(forward_float(params, x_eval), -1) == y_eval)
    )

    # post-training quantization
    in_bits = 8
    x_q, in_scale = quantize_sym(x_eval, in_bits)
    qparams = []
    for i, (w, b) in enumerate(params):
        w_q, w_scale = quantize_sym(w, LAYER_BITS[i])
        qparams.append((w_q, w_scale, None))
    # bias in accumulator units requires the running activation scale
    h_scale = in_scale
    fixed = []
    for i, ((w, b), (w_q, w_scale, _)) in enumerate(zip(params, qparams)):
        b_acc = np.round(np.asarray(b) / (h_scale * w_scale)).astype(np.int64)
        fixed.append((w_q, w_scale, b_acc))
        if i + 1 < len(params):
            # the next layer's activation scale is data-dependent:
            # recompute it by running the quantized forward to here
            h_scale = _activation_scale(fixed, in_scale, x_q, i)

    quant_logits = forward_bitserial(fixed, None, x_q, in_scale)
    quant_acc = float(jnp.mean(jnp.argmax(quant_logits, -1) == y_eval))

    # fixed per-layer output scales for the Rust side (it requantizes
    # with a static grid, not per-batch): layer i<last → the activation
    # scale measured on the eval set; last layer → a logits grid wide
    # enough for the observed range at 16 bits
    out_scales = []
    for i in range(len(fixed) - 1):
        out_scales.append(_activation_scale(fixed, in_scale, x_q, i))
    logit_amax = float(jnp.max(jnp.abs(quant_logits)))
    out_scales.append(max(logit_amax, 1e-6) / ref.max_value(16))

    path = os.path.join(out_dir, "trained_mlp.txt")
    with open(path, "w") as f:
        f.write(f"# trained quantized MLP ({'/'.join(map(str, LAYER_BITS))} bits)\n")
        f.write(f"layers {len(fixed)}\n")
        f.write(f"input_bits {in_bits}\n")
        f.write(f"input_scale {in_scale!r}\n")
        f.write(f"float_acc {float_acc!r}\n")
        f.write(f"quant_acc {quant_acc!r}\n")
        for i, (w_q, w_scale, b_acc) in enumerate(fixed):
            d_in, d_out = w_q.shape
            relu = 1 if i + 1 < len(fixed) else 0
            out_bits = LAYER_BITS[i + 1] if i + 1 < len(fixed) else 16
            f.write(
                f"layer {i} in {d_in} out {d_out} bits {LAYER_BITS[i]} "
                f"w_scale {w_scale!r} relu {relu} out_bits {out_bits} "
                f"out_scale {out_scales[i]!r}\n"
            )
            f.write("w " + " ".join(map(str, np.asarray(w_q).flatten())) + "\n")
            f.write("b " + " ".join(map(str, b_acc)) + "\n")
        # eval set (quantized inputs + labels)
        f.write(f"eval {x_q.shape[0]} {x_q.shape[1]}\n")
        f.write("x " + " ".join(map(str, np.asarray(x_q).flatten())) + "\n")
        f.write("y " + " ".join(map(str, np.asarray(y_eval).flatten())) + "\n")
    print(f"  wrote trained_mlp.txt (float acc {float_acc:.3f}, bit-serial acc {quant_acc:.3f})")
    return {"float_acc": float_acc, "quant_acc": quant_acc, "path": path}


def _activation_scale(fixed, in_scale, x_q, upto: int) -> float:
    """Scale of the activations entering layer `upto+1` when running
    the quantized forward on the eval inputs."""
    h_q, h_scale = x_q, in_scale
    for i in range(upto + 1):
        w_q, w_scale, b_acc = fixed[i]
        acc = bitserial_matmul(h_q, w_q, bits=LAYER_BITS[i], variant="booth")
        acc = acc + jnp.asarray(b_acc, acc.dtype)
        real = jax.nn.relu(acc * (h_scale * w_scale))
        h_q, h_scale = quantize_sym(real, LAYER_BITS[i + 1])
    return h_scale
