"""L2: quantized NN forward passes built on the L1 bit-serial kernel.

The compute graphs here are what the Rust coordinator executes through
PJRT on the request path (after ``aot.py`` lowers them to HLO text).
Every matmul goes through :func:`bitserial_matmul`, so the numbers the
served model produces are exactly the numbers the simulated bitSMM
hardware produces — the co-simulation contract (DESIGN.md).

Per-layer runtime-configurable precision — the paper's motivating
feature ("different layers (or groups of parameters) can use different
bit-widths", SV) — appears as the per-layer ``bits`` entries baked into
each exported executable.

Models (mirroring the workloads the paper's introduction motivates):
  * ``mlp_forward``         — MLP classifier (in-situ data analysis).
  * ``attention_forward``   — single-head attention block (ViT-style
                              transformer workloads, SII-C).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels.bitserial_matmul import bitserial_matmul
from .kernels import ref


def quantize(x, scale: float, bits: int):
    """Symmetric quantization to ``bits``-bit two's complement."""
    q = jnp.round(x / scale)
    return jnp.clip(q, ref.min_value(bits), ref.max_value(bits)).astype(jnp.int32)


def requantize(acc, in_scale: float, out_scale: float, bits: int):
    """Scale an integer accumulator back into the next layer's grid."""
    q = jnp.round(acc * (in_scale / out_scale))
    return jnp.clip(q, ref.min_value(bits), ref.max_value(bits)).astype(jnp.int32)


def linear_bitserial(x_q, w_q, b_q, *, bits: int, variant: str = "booth"):
    """One quantized linear layer: ``x_q·w_q + b`` on the bit-serial
    kernel. ``x_q`` is the multiplier (activations, streamed LSb-first
    in hardware); ``w_q`` the multiplicand (weights, MSb-first)."""
    acc = bitserial_matmul(x_q, w_q, bits=bits, variant=variant)
    return acc + b_q.astype(acc.dtype)


def mlp_forward(
    x_q,
    weights: Sequence,
    biases: Sequence,
    *,
    layer_bits: Sequence[int],
    scales: Sequence[float],
    variant: str = "booth",
):
    """Quantized MLP forward: (linear → ReLU → requantize)* → logits.

    ``layer_bits[i]`` is layer i's operand precision — the per-layer
    bit-width knob. ``scales[i]`` is the activation scale entering
    layer i (``scales[-1]`` is the logits scale).
    """
    h = x_q
    n_layers = len(weights)
    for i, (w_q, b_q) in enumerate(zip(weights, biases)):
        acc = linear_bitserial(h, w_q, b_q, bits=layer_bits[i], variant=variant)
        if i + 1 < n_layers:
            acc = jax.nn.relu(acc)
            # accumulator is in units of (in_scale·w_scale); fold the
            # weight scale into the layer scale handed to us
            h = requantize(acc, scales[i], scales[i + 1], layer_bits[i + 1])
        else:
            h = acc * scales[i]  # dequantized logits
    return h


def attention_forward(x_q, wq, wk, wv, wo, *, bits: int, variant: str = "booth"):
    """Single-head self-attention with bit-serial projections.

    All four projections (Q, K, V, output) run on the bit-serial
    kernel; the attention softmax runs in f32 (the paper's accelerator
    targets the matmul core — SII-C notes matmuls dominate ViT cost).
    Returns f32 activations.
    """
    q = bitserial_matmul(x_q, wq, bits=bits, variant=variant)
    k = bitserial_matmul(x_q, wk, bits=bits, variant=variant)
    v = bitserial_matmul(x_q, wv, bits=bits, variant=variant)
    d = q.shape[-1]
    att = jax.nn.softmax(q @ k.T / jnp.sqrt(jnp.float32(d)), axis=-1)
    ctx = att @ v
    # requantize the context back onto the integer grid for the output
    # projection (scale chosen so the ctx range maps onto `bits` bits)
    ctx_scale = jnp.maximum(jnp.max(jnp.abs(ctx)), 1e-6) / ref.max_value(bits)
    ctx_q = jnp.clip(
        jnp.round(ctx / ctx_scale), ref.min_value(bits), ref.max_value(bits)
    ).astype(jnp.int32)
    out = bitserial_matmul(ctx_q, wo, bits=bits, variant=variant)
    return out * ctx_scale


def make_mlp_params(key, layer_dims: Sequence[int], *, layer_bits: Sequence[int]):
    """Random quantized MLP parameters (weights int32 on each layer's
    grid, biases int32). Used by AOT export and tests."""
    ws, bs = [], []
    for i, (d_in, d_out) in enumerate(zip(layer_dims[:-1], layer_dims[1:])):
        key, k1, k2 = jax.random.split(key, 3)
        bits = layer_bits[i]
        hi = ref.max_value(bits)
        lo = ref.min_value(bits)
        ws.append(jax.random.randint(k1, (d_in, d_out), lo // 2, hi // 2 + 1, jnp.int32))
        bs.append(jax.random.randint(k2, (d_out,), lo, hi + 1, jnp.int32))
    return ws, bs


@functools.partial(jax.jit, static_argnames=("bits", "variant"))
def matmul_entry(a, b, *, bits: int, variant: str = "booth"):
    """The unit-of-work executable the Rust coordinator calls per tile
    batch: a bare bit-serial matmul, f32 accumulator."""
    return (bitserial_matmul(a, b, bits=bits, variant=variant),)


@functools.partial(jax.jit, static_argnames=("bits", "variant"))
def matmul_entry_exact(a, b, *, bits: int, variant: str = "booth"):
    """f64-accumulator variant: exact up to 16-bit operands (used for
    wide-precision layers and cross-validation against the simulator)."""
    return (bitserial_matmul(a, b, bits=bits, variant=variant, acc_dtype=jnp.float64),)
