//! End-to-end serving driver — the system-level validation required by
//! the paper's future work (§V: "bitSMM should be integrated into a
//! complete NN accelerator to benchmark end-to-end workloads").
//!
//! All layers compose here:
//!   L1 Pallas bit-plane kernel → L2 JAX quantized model → AOT HLO
//!   artifacts → Rust PJRT engine thread → dynamic batcher → tiler +
//!   per-layer precision → cycle-accounted serving, with results
//!   cross-validated against the cycle-accurate hardware simulator.
//!
//! Workloads (the space use cases of §I), **all served through the
//! same `serve_all` path** — the server takes tensor-shaped requests,
//! so the conv and attention zoo models are no longer offline-only:
//!   1. MLP classifier over instrument vectors (batch-stacked rows).
//!   2. CNN over 16×16 payload tiles (per-item image requests,
//!      conv→im2col, packed-vs-native cross-check).
//!   3. Transformer attention block (per-item token-matrix requests,
//!      packed-vs-native cross-check).
//!   4. Trained classifier accuracy (when the artifact exists).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use bitsmm::coordinator::{
    serve_all, shaped_inputs, Backend, BatcherConfig, Scheduler, ServerConfig,
};
use bitsmm::nn::model::{attention_zoo, cnn_zoo, mlp_zoo, Model};
use bitsmm::nn::tensor::QTensor;
use bitsmm::report::{f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;
use std::sync::Arc;

/// Serve a zoo model end-to-end on Native, cross-check request 0
/// against a direct forward, re-serve on Packed and on the
/// instruction-driven device backend asserting bit identity against
/// both, then print the serving table.
fn serve_tensor_workload(
    title: &str,
    model: Arc<Model>,
    sa: SaConfig,
    n_requests: usize,
    seed: u64,
) -> bitsmm::Result<()> {
    let ins = shaped_inputs(&model, n_requests, seed);
    let mut cfg = ServerConfig::new(sa, Backend::Native);
    cfg.workers = 2;
    let t0 = std::time::Instant::now();
    let (responses, report, metrics) = serve_all(model.clone(), cfg, ins.clone())?;
    let wall = t0.elapsed();
    assert_eq!(metrics.requests, n_requests as u64);
    assert_eq!(metrics.errors, 0);

    // cross-check request 0 against a direct forward of the same model
    let x0 = QTensor::new(
        ins[0].data.clone(),
        ins[0].shape.clone(),
        model.input_scale,
        model.input_bits,
    )?;
    let mut direct = Scheduler::new(sa, Backend::Native);
    let y0 = model.forward(&x0, &mut direct)?;
    let expect: Vec<f64> = y0.data.iter().map(|&q| q as f64 * y0.scale).collect();
    assert_eq!(responses[0].output, Ok(expect), "served vs direct forward");

    // the serving-path MAC accounting equals the static census for the
    // same request count (per-item batches included)
    let census = model.stats(n_requests).macs;
    assert_eq!(report.macs, census, "served MACs vs census");

    // packed backend serves bit-identical outputs
    let mut pcfg = ServerConfig::new(sa, Backend::Packed);
    pcfg.workers = 2;
    let (packed, preport, _) = serve_all(model.clone(), pcfg, ins.clone())?;
    assert!(preport.packed_execs > 0, "packed engine must have executed");
    for (a, b) in responses.iter().zip(&packed) {
        assert_eq!(a.output, b.output, "native vs packed diverged at id {}", a.id);
    }

    // the instruction-driven device backend serves the same integers,
    // streaming every tile's bit-planes through the fetch/execute/
    // writeback queue of the cycle-accurate simulator
    let mut dcfg = ServerConfig::new(sa, Backend::Simulate);
    dcfg.workers = 1;
    let (device, _, dmetrics) = serve_all(model.clone(), dcfg, ins)?;
    for (a, b) in responses.iter().zip(&device) {
        assert_eq!(a.output, b.output, "native vs device diverged at id {}", a.id);
    }
    assert!(dmetrics.device.tiles > 0, "device backend must have streamed tiles");

    let p = metrics.latency.percentiles(&[50.0, 95.0, 99.0]);
    let mut t = Table::new(title, &["metric", "value"]);
    t.row(&["model".into(), format!("{} (input {:?})", model.name, model.input_shape)]);
    t.row(&["requests".into(), format!("{n_requests}")]);
    t.row(&["output len / request".into(),
        format!("{}", responses[0].output.as_ref().unwrap().len())]);
    t.row(&["wall time".into(), format!("{wall:?}")]);
    t.row(&["mean batch".into(), f(metrics.mean_batch())]);
    t.row(&["p50 / p95 / p99 latency (us)".into(), format!("{} / {} / {}", p[0], p[1], p[2])]);
    t.row(&["MACs served (== census)".into(), format!("{}", report.macs)]);
    t.row(&["hw cycles (timing model)".into(), format!("{}", report.hw_cycles)]);
    t.row(&["hw GOPS @300MHz".into(), f(report.hw_gops(300e6))]);
    t.row(&["packed vs native".into(), "bit-identical".into()]);
    t.row(&["device vs native".into(), "bit-identical".into()]);
    t.row(&[
        "device tiles / fetch overlap cycles".into(),
        format!("{} / {}", dmetrics.device.tiles, dmetrics.device.overlap_cycles),
    ]);
    print!("{}", t.render());
    Ok(())
}

fn main() -> bitsmm::Result<()> {
    let sa = SaConfig::new(4, 16, MacVariant::Booth);

    // ---------------- workload 1: batched MLP serving over PJRT ------
    let artifact_dir = bitsmm::runtime::default_artifact_dir();
    let backend = match bitsmm::runtime::EngineHandle::spawn(&artifact_dir) {
        Ok((engine, _join)) => {
            let warmed = engine.warm_up()?;
            println!("[e2e] PJRT engine up: {warmed} artifacts compiled");
            Backend::Pjrt(engine)
        }
        Err(e) => {
            println!("[e2e] PJRT unavailable ({e:#}); falling back to native backend");
            Backend::Native
        }
    };

    let model = Arc::new(mlp_zoo(1));
    let n_requests = 256usize;
    let mut cfg = ServerConfig::new(sa, backend);
    cfg.workers = 2;
    cfg.batcher = BatcherConfig {
        max_batch: 8, // matches the exported artifact batch shape
        linger: std::time::Duration::from_millis(2),
        ..BatcherConfig::default()
    };

    let inputs = shaped_inputs(&model, n_requests, 7);

    let t0 = std::time::Instant::now();
    let (responses, report, metrics) = serve_all(model.clone(), cfg, inputs.clone())?;
    let wall = t0.elapsed();
    assert_eq!(responses.len(), n_requests);

    // cross-validate a slice of responses against the cycle-accurate
    // hardware simulator (bit-exact co-simulation contract)
    let mut sim_sched = Scheduler::new(sa, Backend::Simulate);
    for (i, resp) in responses.iter().take(3).enumerate() {
        let x = QTensor::new(inputs[i].data.clone(), vec![1, 64], model.input_scale, model.input_bits)?;
        let y = model.forward(&x, &mut sim_sched.as_exec())?;
        let expect: Vec<f64> = y.data.iter().map(|&q| q as f64 * y.scale).collect();
        assert_eq!(resp.output, Ok(expect), "request {i}: served vs simulated hardware");
    }
    println!("[e2e] served outputs bit-match the cycle-accurate hardware simulation");

    let p = metrics.latency.percentiles(&[50.0, 95.0, 99.0]);
    let mut t = Table::new("E2E workload 1 — MLP serving (64→64→32→10, per-layer 8/4/4 bits)", &["metric", "value"]);
    t.row(&["requests".into(), format!("{n_requests}")]);
    t.row(&["wall time".into(), format!("{wall:?}")]);
    t.row(&["throughput (req/s)".into(), f(n_requests as f64 / wall.as_secs_f64())]);
    t.row(&["mean batch".into(), f(metrics.mean_batch())]);
    t.row(&["p50 / p95 / p99 latency (us)".into(), format!("{} / {} / {}", p[0], p[1], p[2])]);
    t.row(&["MACs served".into(), format!("{}", report.macs)]);
    t.row(&["hw cycles (timing model)".into(), format!("{}", report.hw_cycles)]);
    t.row(&["hw GOPS @300MHz".into(), f(report.hw_gops(300e6))]);
    t.row(&["hw inference latency @300MHz".into(),
        format!("{:.1} us/req", report.hw_cycles as f64 / n_requests as f64 / 300e6 * 1e6)]);
    t.row(&["pjrt hits / native fallbacks".into(), format!("{} / {}", report.pjrt_hits, report.native_fallbacks)]);
    print!("{}", t.render());

    // ---------------- workload 2: CNN payload tiles, served ----------
    serve_tensor_workload(
        "E2E workload 2 — CNN 16x16 payload tiles served (per-item batches)",
        Arc::new(cnn_zoo(2)),
        sa,
        16,
        8,
    )?;

    // ---------------- workload 3: attention blocks, served -----------
    serve_tensor_workload(
        "E2E workload 3 — transformer attention served (16 tokens, d=32)",
        Arc::new(attention_zoo(3)),
        sa,
        16,
        9,
    )?;

    // ---------------- workload 4: trained classifier -----------------
    // A genuinely trained (JAX/SGD) quantized model: measure the
    // accuracy the accelerator delivers on its held-out eval split.
    let trained_path = artifact_dir.join("trained_mlp.txt");
    match bitsmm::nn::weights_io::load_trained(&trained_path) {
        Ok(bundle) => {
            let mut sched = Scheduler::new(sa, Backend::Native);
            let t0 = std::time::Instant::now();
            let acc = bitsmm::nn::weights_io::evaluate(&bundle, &mut sched.as_exec())?;
            let wall = t0.elapsed();
            let mut t = Table::new(
                "E2E workload 4 — trained classifier (64-64-32-10, per-layer 8/4/4)",
                &["metric", "value"],
            );
            t.row(&["eval samples".into(), format!("{}", bundle.eval_n)]);
            t.row(&["float accuracy (export)".into(), f(bundle.float_acc)]);
            t.row(&["bit-serial accuracy (python)".into(), f(bundle.python_quant_acc)]);
            t.row(&["bit-serial accuracy (rust-served)".into(), f(acc)]);
            t.row(&["hw cycles (whole split)".into(), format!("{}", sched.report.hw_cycles)]);
            t.row(&["hw latency/inference @300MHz".into(),
                format!("{:.1} us", sched.report.hw_cycles as f64 / bundle.eval_n as f64 / 300e6 * 1e6)]);
            t.row(&["host wall".into(), format!("{wall:?}")]);
            print!("{}", t.render());
        }
        Err(e) => println!("[e2e] trained model unavailable ({e:#})"),
    }

    println!("\ne2e OK — all three zoo models served end-to-end; packed bit-identical; co-simulation bit-exact.");
    Ok(())
}
