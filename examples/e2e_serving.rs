//! End-to-end serving driver — the system-level validation required by
//! the paper's future work (§V: "bitSMM should be integrated into a
//! complete NN accelerator to benchmark end-to-end workloads").
//!
//! All layers compose here:
//!   L1 Pallas bit-plane kernel → L2 JAX quantized model → AOT HLO
//!   artifacts → Rust PJRT engine thread → dynamic batcher → tiler +
//!   per-layer precision → cycle-accounted serving, with results
//!   cross-validated against the cycle-accurate hardware simulator.
//!
//! Workloads (the space use cases of §I):
//!   1. MLP classifier over instrument vectors (batched serving, PJRT).
//!   2. CNN over a 16×16 payload tile (native backend, conv→im2col).
//!   3. Transformer attention block (native backend).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use bitsmm::coordinator::{serve_all, Backend, BatcherConfig, Scheduler, ServerConfig};
use bitsmm::nn::model::{attention_zoo, cnn_zoo, forward_cnn, mlp_zoo};
use bitsmm::nn::tensor::QTensor;
use bitsmm::prng::Pcg32;
use bitsmm::report::{f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;
use std::sync::Arc;

fn main() -> bitsmm::Result<()> {
    let sa = SaConfig::new(4, 16, MacVariant::Booth);

    // ---------------- workload 1: batched MLP serving over PJRT ------
    let artifact_dir = bitsmm::runtime::default_artifact_dir();
    let backend = match bitsmm::runtime::EngineHandle::spawn(&artifact_dir) {
        Ok((engine, _join)) => {
            let warmed = engine.warm_up()?;
            println!("[e2e] PJRT engine up: {warmed} artifacts compiled");
            Backend::Pjrt(engine)
        }
        Err(e) => {
            println!("[e2e] PJRT unavailable ({e:#}); falling back to native backend");
            Backend::Native
        }
    };

    let model = Arc::new(mlp_zoo(1));
    let n_requests = 256usize;
    let mut cfg = ServerConfig::new(sa, backend);
    cfg.workers = 2;
    cfg.batcher = BatcherConfig {
        max_batch: 8, // matches the exported artifact batch shape
        linger: std::time::Duration::from_millis(2),
    };

    let mut rng = Pcg32::new(7);
    let inputs: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| (0..64).map(|_| rng.range_i32(-128, 127)).collect())
        .collect();

    let t0 = std::time::Instant::now();
    let (responses, report, metrics) = serve_all(model.clone(), cfg, inputs.clone())?;
    let wall = t0.elapsed();
    assert_eq!(responses.len(), n_requests);

    // cross-validate a slice of responses against the cycle-accurate
    // hardware simulator (bit-exact co-simulation contract)
    let mut sim_sched = Scheduler::new(sa, Backend::Simulate);
    for (i, resp) in responses.iter().take(3).enumerate() {
        let x = QTensor::new(inputs[i].clone(), vec![1, 64], model.input_scale, model.input_bits)?;
        let y = model.forward(&x, &mut sim_sched.as_exec())?;
        let expect: Vec<f64> = y.data.iter().map(|&q| q as f64 * y.scale).collect();
        assert_eq!(resp.output, expect, "request {i}: served vs simulated hardware");
    }
    println!("[e2e] served outputs bit-match the cycle-accurate hardware simulation");

    let mut t = Table::new("E2E workload 1 — MLP serving (64→64→32→10, per-layer 8/4/4 bits)", &["metric", "value"]);
    t.row(&["requests".into(), format!("{n_requests}")]);
    t.row(&["wall time".into(), format!("{wall:?}")]);
    t.row(&["throughput (req/s)".into(), f(n_requests as f64 / wall.as_secs_f64())]);
    t.row(&["mean batch".into(), f(metrics.mean_batch())]);
    t.row(&["p50 / p95 / p99 latency (us)".into(),
        format!("{} / {} / {}",
            metrics.latency.percentile_us(50.0),
            metrics.latency.percentile_us(95.0),
            metrics.latency.percentile_us(99.0))]);
    t.row(&["MACs served".into(), format!("{}", report.macs)]);
    t.row(&["hw cycles (timing model)".into(), format!("{}", report.hw_cycles)]);
    t.row(&["hw GOPS @300MHz".into(), f(report.hw_gops(300e6))]);
    t.row(&["hw inference latency @300MHz".into(),
        format!("{:.1} us/req", report.hw_cycles as f64 / n_requests as f64 / 300e6 * 1e6)]);
    t.row(&["pjrt hits / native fallbacks".into(), format!("{} / {}", report.pjrt_hits, report.native_fallbacks)]);
    print!("{}", t.render());

    // ---------------- workload 2: CNN payload tile -------------------
    let cnn = cnn_zoo(2);
    let mut rng = Pcg32::new(8);
    let img = QTensor::new(
        (0..256).map(|_| rng.range_i32(-128, 127)).collect(),
        vec![1, 16, 16],
        cnn.input_scale,
        cnn.input_bits,
    )?;
    let mut sched = Scheduler::new(sa, Backend::Native);
    let t0 = std::time::Instant::now();
    let y = forward_cnn(&cnn, &img, &mut sched.as_exec())?;
    let cnn_wall = t0.elapsed();
    let stats = cnn.stats(1);
    let mut t = Table::new("E2E workload 2 — CNN 16x16 payload tile", &["metric", "value"]);
    t.row(&["output shape".into(), format!("{:?}", y.shape)]);
    t.row(&["total MACs (census)".into(), format!("{}", stats.macs)]);
    t.row(&["hw cycles".into(), format!("{}", sched.report.hw_cycles)]);
    t.row(&["hw latency @300MHz".into(), format!("{:.1} us", sched.report.hw_cycles as f64 / 300e6 * 1e6)]);
    t.row(&["host wall".into(), format!("{cnn_wall:?}")]);
    t.row(&["tiles".into(), format!("{}", sched.report.tiles)]);
    print!("{}", t.render());

    // ---------------- workload 3: attention block --------------------
    let attn = attention_zoo(3);
    let mut rng = Pcg32::new(9);
    let x = QTensor::new(
        (0..16 * 32).map(|_| rng.range_i32(-128, 127)).collect(),
        vec![16, 32],
        attn.input_scale,
        attn.input_bits,
    )?;
    let mut sched = Scheduler::new(sa, Backend::Native);
    let y = attn.forward(&x, &mut sched.as_exec())?;
    let mut t = Table::new("E2E workload 3 — transformer attention block (16 tokens, d=32)", &["metric", "value"]);
    t.row(&["output shape".into(), format!("{:?}", y.shape)]);
    t.row(&["projection matmuls".into(), format!("{}", sched.report.matmuls)]);
    t.row(&["hw cycles".into(), format!("{}", sched.report.hw_cycles)]);
    t.row(&["hw latency @300MHz".into(), format!("{:.1} us", sched.report.hw_cycles as f64 / 300e6 * 1e6)]);
    print!("{}", t.render());

    // ---------------- workload 4: trained classifier -----------------
    // A genuinely trained (JAX/SGD) quantized model: measure the
    // accuracy the accelerator delivers on its held-out eval split.
    let trained_path = artifact_dir.join("trained_mlp.txt");
    match bitsmm::nn::weights_io::load_trained(&trained_path) {
        Ok(bundle) => {
            let mut sched = Scheduler::new(sa, Backend::Native);
            let t0 = std::time::Instant::now();
            let acc = bitsmm::nn::weights_io::evaluate(&bundle, &mut sched.as_exec())?;
            let wall = t0.elapsed();
            let mut t = Table::new(
                "E2E workload 4 — trained classifier (64-64-32-10, per-layer 8/4/4)",
                &["metric", "value"],
            );
            t.row(&["eval samples".into(), format!("{}", bundle.eval_n)]);
            t.row(&["float accuracy (export)".into(), f(bundle.float_acc)]);
            t.row(&["bit-serial accuracy (python)".into(), f(bundle.python_quant_acc)]);
            t.row(&["bit-serial accuracy (rust-served)".into(), f(acc)]);
            t.row(&["hw cycles (whole split)".into(), format!("{}", sched.report.hw_cycles)]);
            t.row(&["hw latency/inference @300MHz".into(),
                format!("{:.1} us", sched.report.hw_cycles as f64 / bundle.eval_n as f64 / 300e6 * 1e6)]);
            t.row(&["host wall".into(), format!("{wall:?}")]);
            print!("{}", t.render());
        }
        Err(e) => println!("[e2e] trained model unavailable ({e:#})"),
    }

    println!("\ne2e OK — all workloads served; co-simulation bit-exact.");
    Ok(())
}
