//! TMR fault-injection campaign — exercising the paper's §I claim that
//! bit-serial MACs make hardware redundancy cheap: a bit-serial MAC is
//! an AND gate plus adder(s), so triplication costs ~3× a tiny unit
//! (vs 3× a full parallel multiplier).
//!
//! Injects single-event upsets (SEUs) at random cycles/replicas/bits
//! during dot products and measures: fault masking rate under TMR,
//! unprotected-failure rate without it, and the residual double-fault
//! window.
//!
//! ```sh
//! cargo run --release --example tmr_faults
//! ```

use bitsmm::prng::Pcg32;
use bitsmm::report::{f, Table};
use bitsmm::sim::mac_common::MacVariant;
use bitsmm::sim::tmr::tmr_dot_with_faults;

fn main() -> bitsmm::Result<()> {
    let mut rng = Pcg32::new(0x5eu64);
    let bits = 8u32;
    let len = 32usize;
    let trials = 400usize;

    let mut t = Table::new(
        "TMR fault-injection campaign (8-bit dot products, len 32)",
        &["scenario", "variant", "trials", "correct", "rate"],
    );

    for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
        // -------- single SEU per dot product, TMR voted ---------------
        let mut correct = 0usize;
        let mut divergences = 0usize;
        for _ in 0..trials {
            let (mc, ml) = rand_vectors(&mut rng, len, bits);
            let cycle = rng.below(((len + 1) as u32) * bits) as u64;
            let fault = (cycle, rng.below(3) as usize, rng.below(48));
            let (voted, reference, div) =
                tmr_dot_with_faults(variant, &mc, &ml, bits, 48, &[fault]);
            if voted == reference {
                correct += 1;
            }
            if div {
                divergences += 1;
            }
        }
        t.row(&[
            "1 SEU, TMR voter".into(),
            variant.name().into(),
            trials.to_string(),
            correct.to_string(),
            f(correct as f64 / trials as f64),
        ]);
        assert_eq!(correct, trials, "single faults must always be masked");
        assert!(divergences > trials / 2, "faults should be observable pre-vote");

        // -------- single SEU, NO redundancy (baseline failure rate) ---
        // emulate by checking whether the faulty replica alone is wrong
        let mut unprotected_wrong = 0usize;
        for _ in 0..trials {
            let (mc, ml) = rand_vectors(&mut rng, len, bits);
            let cycle = rng.below(((len + 1) as u32) * bits) as u64;
            // inject into replica 0 and read replica 0 via raw()
            let fault = (cycle, 0usize, rng.below(24)); // low bits: live range
            let (_, reference, _) = tmr_dot_with_faults(variant, &mc, &ml, bits, 48, &[]);
            let (voted_with_double, _, _) = tmr_dot_with_faults(
                variant,
                &mc,
                &ml,
                bits,
                48,
                &[fault, (fault.0, 1, fault.2), (fault.0, 2, fault.2)],
            );
            // all three replicas hit identically == unprotected behaviour
            if voted_with_double != reference {
                unprotected_wrong += 1;
            }
        }
        t.row(&[
            "1 SEU, no TMR (3x same hit)".into(),
            variant.name().into(),
            trials.to_string(),
            (trials - unprotected_wrong).to_string(),
            f((trials - unprotected_wrong) as f64 / trials as f64),
        ]);

        // -------- double SEU in the same cycle+bit (TMR defeat) -------
        let mut defeated = 0usize;
        for _ in 0..trials {
            let (mc, ml) = rand_vectors(&mut rng, len, bits);
            let cycle = rng.below(((len + 1) as u32) * bits) as u64;
            let bit = rng.below(24);
            let faults = [(cycle, 0usize, bit), (cycle, 1usize, bit)];
            let (voted, reference, _) =
                tmr_dot_with_faults(variant, &mc, &ml, bits, 48, &faults);
            if voted != reference {
                defeated += 1;
            }
        }
        t.row(&[
            "2 SEUs same bit+cycle".into(),
            variant.name().into(),
            trials.to_string(),
            (trials - defeated).to_string(),
            f((trials - defeated) as f64 / trials as f64),
        ]);
    }
    print!("{}", t.render());

    // cost summary: TMR area from the FPGA model
    let fpga = bitsmm::arch::fpga::FpgaModel::default();
    let base = fpga.implement(
        bitsmm::sim::array::SaConfig::new(4, 16, MacVariant::Booth),
        16,
    );
    println!(
        "\nTMR cost estimate (16x4 Booth): {} LUTs -> ~{} LUTs triplicated (+voters)",
        base.luts,
        base.luts * 3
    );
    println!("tmr_faults OK");
    Ok(())
}

fn rand_vectors(rng: &mut Pcg32, len: usize, bits: u32) -> (Vec<i32>, Vec<i32>) {
    let lo = bitsmm::bits::twos::min_value(bits);
    let hi = bitsmm::bits::twos::max_value(bits);
    (
        (0..len).map(|_| rng.range_i32(lo, hi)).collect(),
        (0..len).map(|_| rng.range_i32(lo, hi)).collect(),
    )
}
