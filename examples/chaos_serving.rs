//! Chaos-serving drill — the resilience and integrity layers end to
//! end (DESIGN.md §Resilience, §Integrity).
//!
//! Seven phases against the packed backend:
//!
//! 1. **Baseline** — a fault-free run records every request's exact
//!    output (the bit-identity reference).
//! 2. **Fault injection** — a deterministic plan (worker panic,
//!    dropped pool job, SEU bit-flip) with ABFT on: the server keeps
//!    serving, every submitter gets a terminal typed answer, and every
//!    request that still produced an output matches the baseline
//!    bit for bit.
//! 3. **Overload** — a stalled worker (injected delay) plus a bounded
//!    queue and an age budget: a second submission wave is refused at
//!    admission and the stale queue is shed — no submitter ever hangs.
//! 4. **Deadlines** — pre-expired deadlines are answered
//!    `DeadlineExceeded` at dequeue instead of being served late.
//! 5. **Degradation** — under backlog, low-priority requests serve on
//!    the precision-degraded clone; outputs still match the baseline
//!    (the downshift is clamped to be bit-exact).
//! 6. **Memory SEU + scrubbing** — a `mem@N` fault flips a bit in a
//!    *resident* packed plane (corrupting state, not one computation);
//!    the background scrubber detects it via the per-plane signature
//!    and repairs by re-packing from the golden-verified weights,
//!    while the ABFT ladder guards any batch that races the sweep —
//!    outputs stay bit-identical with `unmasked=0`.
//! 7. **Memory SEU, scrubbing off** — the on-ABFT-miss escalation
//!    ladder alone detects, repairs, and classifies the resident
//!    upset as *persistent* (a transient flip would leave the planes
//!    signature-clean).
//!
//! Prints a human summary line; phases 6 and 7 additionally append
//! JSONL metrics snapshots (`chaos_metrics_scrub.jsonl`,
//! `chaos_metrics_ladder.jsonl`) that CI gates structurally with
//! `bitsmm obs --require 'faults.unmasked=0,scrub.repaired>=1'`
//! instead of grepping the summary text.
//!
//! ```sh
//! cargo run --release --example chaos_serving
//! ```

use bitsmm::coordinator::{
    shaped_inputs, Backend, BatcherConfig, DegradePolicy, FaultPlan, FaultState, InferenceServer,
    Metrics, Request, Response, ServeError, ServerConfig,
};
use bitsmm::nn::model::mlp_headroom_zoo;
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;
use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const N_REQUESTS: usize = 24;

fn base_cfg() -> ServerConfig {
    let sa = SaConfig::new(4, 16, MacVariant::Booth);
    let mut cfg = ServerConfig::new(sa, Backend::Packed);
    cfg.workers = 1; // deterministic batch order for the fault plan
    cfg.packed_threads = 2;
    cfg.batcher = BatcherConfig {
        max_batch: 4,
        linger: Duration::from_millis(1),
        ..BatcherConfig::default()
    };
    cfg
}

fn requests() -> Vec<Request> {
    shaped_inputs(&mlp_headroom_zoo(3), N_REQUESTS, 42)
        .into_iter()
        .enumerate()
        .map(|(i, x)| Request::new(i as u64, x))
        .collect()
}

/// Wait for every answer — a submitter that never hears back is the
/// failure mode this whole drill exists to rule out.
fn collect(rxs: Vec<mpsc::Receiver<Response>>) -> Vec<Response> {
    rxs.into_iter()
        .enumerate()
        .map(|(i, rx)| {
            rx.recv()
                .unwrap_or_else(|_| panic!("submitter {i} never got a terminal response"))
        })
        .collect()
}

fn run_phase(cfg: ServerConfig, reqs: Vec<Request>) -> bitsmm::Result<(Vec<Response>, Metrics)> {
    let server = InferenceServer::start(Arc::new(mlp_headroom_zoo(3)), cfg)?;
    let rxs: Vec<_> = reqs.into_iter().map(|r| server.submit(r)).collect();
    let responses = collect(rxs);
    let (_, metrics) = server.shutdown();
    Ok((responses, metrics))
}

fn main() -> bitsmm::Result<()> {
    // ---- phase 1: fault-free baseline --------------------------------
    let (baseline, base_metrics) = run_phase(base_cfg(), requests())?;
    let reference: HashMap<u64, Vec<f64>> = baseline
        .iter()
        .map(|r| (r.id, r.output.clone().expect("baseline run must be clean")))
        .collect();
    assert_eq!(reference.len(), N_REQUESTS);
    assert_eq!(base_metrics.panics, 0);
    println!("phase 1 baseline: {} clean responses", reference.len());

    // ---- phase 2: panic + dropped pool job + SEU, ABFT on ------------
    let mut cfg = base_cfg();
    cfg.abft = true;
    cfg.faults = Some(Arc::new(FaultState::new(FaultPlan::parse(
        "panic@1,drop@2,seu@3,seed=42",
    )?)));
    let (responses, chaos) = run_phase(cfg, requests())?;
    let mut ok = 0usize;
    let mut faulted = 0usize;
    for r in &responses {
        match &r.output {
            Ok(out) => {
                assert_eq!(
                    out, &reference[&r.id],
                    "request {} diverged from the fault-free baseline",
                    r.id
                );
                ok += 1;
            }
            Err(ServeError::WorkerFault(_)) => faulted += 1,
            Err(e) => panic!("unexpected terminal error under fault plan: {e}"),
        }
    }
    assert!(chaos.panics >= 1, "the planned panic must have fired");
    assert!(faulted >= 1, "the panicked batch answers its own requests");
    assert_eq!(ok + faulted, N_REQUESTS);
    assert!(chaos.faults.injected >= 2, "drop + SEU were injected");
    assert_eq!(chaos.faults.unmasked, 0, "ABFT + work stealing mask all");
    println!(
        "phase 2 chaos: {ok} bit-identical, {faulted} worker-faulted, \
         {} faults injected / {} masked",
        chaos.faults.injected,
        chaos.faults.masked()
    );

    // ---- phase 3: overload — bounded admission + age shedding --------
    let mut cfg = base_cfg();
    cfg.batcher.max_queue = 4;
    cfg.batcher.shed_after = Some(Duration::from_millis(10));
    // stall the first batch so the second wave piles up behind it
    cfg.faults = Some(Arc::new(FaultState::new(FaultPlan::parse("delay@0:300ms")?)));
    let server = InferenceServer::start(Arc::new(mlp_headroom_zoo(3)), cfg)?;
    let mut reqs = requests().into_iter();
    let mut rxs = Vec::new();
    // wave 1 fills the first batch; give the worker time to dequeue it
    // and enter the injected 300ms stall
    for req in reqs.by_ref().take(4) {
        rxs.push(server.submit(req));
    }
    std::thread::sleep(Duration::from_millis(50));
    // wave 2 floods the stalled server: the queue holds `max_queue`,
    // the rest are refused at admission, and whatever queued ages far
    // past the 10ms shed budget before the worker comes back
    for req in reqs {
        rxs.push(server.submit(req));
    }
    let responses = collect(rxs);
    let (_, overload) = server.shutdown();
    let mut served = 0usize;
    let (mut rejected, mut shed) = (0usize, 0usize);
    for r in &responses {
        match &r.output {
            Ok(out) => {
                assert_eq!(out, &reference[&r.id], "survivors stay bit-identical");
                served += 1;
            }
            Err(ServeError::Rejected { depth }) => {
                assert!(*depth >= 4, "refused at the admission bound");
                rejected += 1;
            }
            Err(ServeError::Overloaded { waited }) => {
                assert!(*waited >= Duration::from_millis(10));
                shed += 1;
            }
            Err(e) => panic!("unexpected terminal error under overload: {e}"),
        }
    }
    assert_eq!(served + rejected + shed, N_REQUESTS);
    assert!(rejected >= 1, "the bounded queue must refuse the flood");
    assert!(overload.sheds >= 1, "the age budget must shed stale work");
    println!("phase 3 overload: {served} served, {rejected} rejected, {shed} shed");

    // ---- phase 4: pre-expired deadlines ------------------------------
    let now = Instant::now();
    let reqs: Vec<Request> = requests()
        .into_iter()
        .map(|r| {
            let expired = r.id % 3 == 0;
            if expired {
                r.with_deadline(now)
            } else {
                r
            }
        })
        .collect();
    let (responses, deadlines) = run_phase(base_cfg(), reqs)?;
    let mut missed = 0usize;
    for r in &responses {
        match &r.output {
            Ok(out) => assert_eq!(out, &reference[&r.id], "on-time requests unaffected"),
            Err(ServeError::DeadlineExceeded) => {
                assert_eq!(r.id % 3, 0, "only the expired requests miss");
                missed += 1;
            }
            Err(e) => panic!("unexpected terminal error in deadline phase: {e}"),
        }
    }
    assert_eq!(missed, N_REQUESTS.div_ceil(3));
    assert_eq!(deadlines.deadline_misses as usize, missed);
    println!("phase 4 deadlines: {missed} expired requests answered at dequeue");

    // ---- phase 5: degraded low-priority serving ----------------------
    let mut cfg = base_cfg();
    cfg.degrade = Some(DegradePolicy {
        high_water: 0, // any backlog downshifts low-priority work
        floor_bits: 4,
    });
    // stall batch 0 so later submissions queue up behind it
    cfg.faults = Some(Arc::new(FaultState::new(FaultPlan::parse("delay@0:150ms")?)));
    let reqs: Vec<Request> = requests().into_iter().map(Request::low_priority).collect();
    let (responses, degrade) = run_phase(cfg, reqs)?;
    for r in &responses {
        let out = r.output.as_ref().unwrap_or_else(|e| panic!("{}: {e}", r.id));
        assert_eq!(
            out, &reference[&r.id],
            "degraded serving must stay bit-identical (request {})",
            r.id
        );
    }
    assert!(
        degrade.degraded >= 1,
        "backlogged low-priority requests must take the degraded clone"
    );
    println!(
        "phase 5 degrade: {} responses, {} served at narrowed precision, all bit-identical",
        responses.len(),
        degrade.degraded
    );

    // ---- phase 6: memory SEU + background scrubbing ------------------
    let mut cfg = base_cfg();
    cfg.abft = true;
    cfg.scrub_ms = 2;
    // CI parses the final metrics snapshot of this phase (`bitsmm obs
    // --metrics chaos_metrics_scrub.jsonl`) instead of grepping the
    // summary line below
    cfg.metrics_file = Some("chaos_metrics_scrub.jsonl".into());
    cfg.faults = Some(Arc::new(FaultState::new(FaultPlan::parse("mem@2,seed=7")?)));
    let server = InferenceServer::start(Arc::new(mlp_headroom_zoo(3)), cfg)?;
    let mut reqs = requests().into_iter();
    let mut rxs = Vec::new();
    // wave 1 runs through the fault batch: the SEU lands in a resident
    // packed plane — corrupted *state*, not one corrupted computation
    for req in reqs.by_ref().take(12) {
        rxs.push(server.submit(req));
    }
    // give the 2ms scrubber a window to sweep, catch the flipped
    // plane's signature, and repair by re-packing from the golden
    // weights before wave 2 arrives (any batch racing the sweep is
    // still guarded by the ABFT ladder — same counters, same repair)
    std::thread::sleep(Duration::from_millis(30));
    for req in reqs {
        rxs.push(server.submit(req));
    }
    let responses = collect(rxs);
    let (_, mem) = server.shutdown();
    for r in &responses {
        let out = r.output.as_ref().unwrap_or_else(|e| panic!("{}: {e}", r.id));
        assert_eq!(
            out, &reference[&r.id],
            "request {} corrupted by the memory SEU",
            r.id
        );
    }
    assert!(mem.faults.mem_seu >= 1, "the planned memory SEU must fire");
    assert!(mem.scrub.sweeps >= 1, "the background scrubber must sweep");
    assert!(
        mem.scrub.detected >= 1 && mem.scrub.repaired >= 1,
        "the flipped plane must be detected and repaired by re-pack"
    );
    assert_eq!(mem.scrub.quarantined, 0, "golden weights verify, nothing quarantines");
    assert_eq!(mem.faults.unmasked, 0, "no corrupt output reached a response");
    println!(
        "phase 6 scrub: mem-seu injected={} sweeps={} detected={} repaired={} unmasked={}",
        mem.faults.mem_seu,
        mem.scrub.sweeps,
        mem.scrub.detected,
        mem.scrub.repaired,
        mem.faults.unmasked
    );

    // ---- phase 7: memory SEU, scrubbing off — the ladder alone -------
    let mut cfg = base_cfg();
    cfg.abft = true; // scrub_ms stays 0: the ABFT ladder is the only defense
    cfg.metrics_file = Some("chaos_metrics_ladder.jsonl".into());
    cfg.faults = Some(Arc::new(FaultState::new(FaultPlan::parse("mem@2,seed=13")?)));
    let (responses, ladder) = run_phase(cfg, requests())?;
    for r in &responses {
        let out = r.output.as_ref().unwrap_or_else(|e| panic!("{}: {e}", r.id));
        assert_eq!(
            out, &reference[&r.id],
            "request {} corrupted with scrubbing off",
            r.id
        );
    }
    assert!(ladder.faults.mem_seu >= 1, "the planned memory SEU must fire");
    assert!(
        ladder.faults.masked_persistent >= 1,
        "resident corruption classifies persistent (the planes themselves are corrupt)"
    );
    assert_eq!(
        ladder.faults.masked_transient, 0,
        "no transient flips were injected in this phase"
    );
    assert_eq!(ladder.faults.unmasked, 0);
    assert_eq!(ladder.scrub.sweeps, 0, "no scrubber ran");
    assert!(ladder.scrub.repaired >= 1, "the ladder repaired inline by re-pack");
    println!(
        "phase 7 ladder: mem-seu injected={} masked transient={} persistent={} unmasked={}",
        ladder.faults.mem_seu,
        ladder.faults.masked_transient,
        ladder.faults.masked_persistent,
        ladder.faults.unmasked
    );

    // ---- greppable summary (CI contract) -----------------------------
    println!(
        "chaos_serving summary: answered={} panics={} sheds={} rejected={} \
         deadline_misses={} degraded={} injected={} masked={} unmasked={} \
         mem_seu={} scrub_repaired={}",
        7 * N_REQUESTS,
        chaos.panics,
        overload.sheds,
        overload.rejected,
        deadlines.deadline_misses,
        degrade.degraded,
        chaos.faults.injected
            + overload.faults.injected
            + degrade.faults.injected
            + mem.faults.injected
            + ladder.faults.injected,
        chaos.faults.masked()
            + overload.faults.masked()
            + degrade.faults.masked()
            + mem.faults.masked()
            + ladder.faults.masked(),
        chaos.faults.unmasked
            + overload.faults.unmasked
            + degrade.faults.unmasked
            + mem.faults.unmasked
            + ladder.faults.unmasked,
        mem.faults.mem_seu + ladder.faults.mem_seu,
        mem.scrub.repaired + ladder.scrub.repaired,
    );
    println!("chaos_serving: OK");
    Ok(())
}
