//! Quickstart: simulate one bit-serial matrix multiplication and check
//! it against plain integer arithmetic, then show how the cycle count
//! follows the paper's eq. 8.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use bitsmm::arch::throughput::{bitsmm_cycles, gops, peak_op_per_cycle};
use bitsmm::coordinator::{Backend, Scheduler};
use bitsmm::prng::Pcg32;
use bitsmm::report::{f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::driver::ref_matmul_i64;
use bitsmm::sim::mac_common::MacVariant;

fn main() -> bitsmm::Result<()> {
    // A 16×4 array (paper notation: columns × rows), Booth MACs.
    let sa = SaConfig::new(4, 16, MacVariant::Booth);

    // An 8-bit 4×64×16 matmul — one SA tile with a long dot product.
    let (m, k, n, bits) = (4usize, 64usize, 16usize, 8u32);
    let mut rng = Pcg32::new(2026);
    let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(-128, 127)).collect();
    let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(-128, 127)).collect();

    // Run it on the cycle-accurate simulator through the coordinator.
    let mut sched = Scheduler::new(sa, Backend::Simulate);
    let result = sched.matmul(&a, &b, m, k, n, bits)?;
    assert_eq!(result, ref_matmul_i64(&a, &b, m, k, n));

    let eq8 = bitsmm_cycles(k as u64, bits);
    let readout = (sa.rows * sa.cols) as u64;
    let mut t = Table::new("quickstart — 4x64x16 @ 8 bit on a 16x4 bitSMM", &["metric", "value"]);
    t.row(&["simulated cycles (measured)".into(), format!("{}", sched.report.hw_cycles)]);
    t.row(&["eq. 8 compute cycles".into(), format!("{eq8}")]);
    t.row(&["readout cycles (rows·cols)".into(), format!("{readout}")]);
    t.row(&["MAC ops".into(), format!("{}", sched.report.macs)]);
    t.row(&["achieved OP/cycle".into(), f(sched.report.macs as f64 / sched.report.hw_cycles as f64)]);
    t.row(&["peak OP/cycle (eq. 10)".into(), f(peak_op_per_cycle(16, 4, bits))]);
    t.row(&["GOPS @ 300 MHz (at peak)".into(), f(gops(peak_op_per_cycle(16, 4, bits), 300e6))]);
    t.row(&["numerics".into(), "bit-exact vs integer reference".into()]);
    print!("{}", t.render());

    // Runtime-configurable precision: the same hardware at 4 bits
    // halves the cycle count (eq. 8 is linear in the operand width).
    let mut sched4 = Scheduler::new(sa, Backend::Simulate);
    let a4: Vec<i32> = a.iter().map(|&v| v.clamp(-8, 7)).collect();
    let b4: Vec<i32> = b.iter().map(|&v| v.clamp(-8, 7)).collect();
    sched4.matmul(&a4, &b4, m, k, n, 4)?;
    println!(
        "precision knob: {} cycles @8b -> {} cycles @4b (x{:.2})",
        sched.report.hw_cycles,
        sched4.report.hw_cycles,
        sched.report.hw_cycles as f64 / sched4.report.hw_cycles as f64
    );
    Ok(())
}
