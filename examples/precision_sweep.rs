//! Per-layer precision sweep — the paper's headline flexibility
//! ("different layers (or groups of parameters) can use different
//! bit-widths", §V) quantified: latency (eq. 8 is linear in width)
//! against weight-quantization SNR, plus the SNR-adaptive policy.
//!
//! ```sh
//! cargo run --release --example precision_sweep
//! ```

use bitsmm::coordinator::{Backend, PrecisionPolicy, Scheduler};
use bitsmm::nn::model::mlp_zoo;
use bitsmm::nn::quant::{quant_snr_db, quantize_symmetric};
use bitsmm::nn::tensor::QTensor;
use bitsmm::prng::Pcg32;
use bitsmm::report::{ascii_plot, f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;

fn main() -> bitsmm::Result<()> {
    let model = mlp_zoo(1);
    let sa = SaConfig::new(4, 16, MacVariant::Booth);

    // ---- uniform-precision sweep ------------------------------------
    let mut t = Table::new(
        "uniform precision sweep (MLP 64-64-32-10)",
        &["bits", "latency vs 16b", "hw cycles/inf", "weight SNR (dB)", "output drift"],
    );
    let mut series = Vec::new();

    // reference output at 16 bits for drift measurement
    let mut rng = Pcg32::new(77);
    let x_full: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
    let reference = run_at_bits(&model, &sa, &x_full, 16)?;

    for bits in [1u32, 2, 3, 4, 6, 8, 12, 16] {
        let policy = PrecisionPolicy::Uniform(bits);
        let frac = policy.latency_fraction(&model)?;
        let (out, cycles) = run_at_bits(&model, &sa, &x_full, bits)?;
        let drift = rms(&out, &reference.0);
        // weight SNR at this width (first layer's weights, representative)
        let w = match &model.layers[0] {
            bitsmm::nn::layers::Layer::Linear(l) => &l.w,
            _ => unreachable!(),
        };
        let real: Vec<f64> = w.data.iter().map(|&q| q as f64 * w.scale).collect();
        let snr = quant_snr_db(&real, &quantize_symmetric(&real, w.shape.clone(), bits)?);
        t.row(&[
            bits.to_string(),
            f(frac),
            format!("{}", cycles),
            f(snr),
            f(drift),
        ]);
        let _ = out;
        series.push((bits as f64, snr.max(0.0)));
    }
    print!("{}", t.render());
    print!(
        "{}",
        ascii_plot("weight SNR vs operand width", &[("snr(dB)", &series)], 12)
    );

    // ---- policy comparison -------------------------------------------
    let mut t = Table::new(
        "precision policies",
        &["policy", "layer widths", "latency vs 16b"],
    );
    for (name, policy) in [
        ("uniform 16", PrecisionPolicy::Uniform(16)),
        ("uniform 8", PrecisionPolicy::Uniform(8)),
        ("per-layer 8/4/4 (paper-style)", PrecisionPolicy::PerLayer(vec![8, 4, 4])),
        ("adaptive snr>=30dB", PrecisionPolicy::Adaptive { snr_target_db: 30.0 }),
        ("adaptive snr>=45dB", PrecisionPolicy::Adaptive { snr_target_db: 45.0 }),
    ] {
        let widths = policy.resolve(&model)?;
        let frac = policy.latency_fraction(&model)?;
        t.row(&[name.into(), format!("{widths:?}"), f(frac)]);
    }
    print!("{}", t.render());
    println!("\nprecision_sweep OK");
    Ok(())
}

/// Run the zoo MLP with every layer clamped to `bits` and return
/// (logits, hw cycles for one inference).
fn run_at_bits(
    model: &bitsmm::nn::model::Model,
    sa: &SaConfig,
    x_real: &[f64],
    bits: u32,
) -> bitsmm::Result<(Vec<f64>, u64)> {
    // clamp a copy of the model onto the `bits` grid
    let mut m = model.clone();
    for layer in &mut m.layers {
        if let bitsmm::nn::layers::Layer::Linear(l) = layer {
            let real: Vec<f64> = l.w.data.iter().map(|&q| q as f64 * l.w.scale).collect();
            l.w = quantize_symmetric(&real, l.w.shape.clone(), bits)?;
            l.bits = bits;
            l.out_bits = bits; // activations live on the same grid
        }
    }
    let xq = quantize_symmetric(x_real, vec![64], bits)?;
    let x = QTensor::new(xq.data, vec![1, 64], xq.scale, bits)?;
    let mut sched = Scheduler::new(*sa, Backend::Native);
    let y = m.forward(&x, &mut sched.as_exec())?;
    Ok((
        y.data.iter().map(|&q| q as f64 * y.scale).collect(),
        sched.report.hw_cycles,
    ))
}

fn rms(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len()) as f64;
    (a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / n).sqrt()
}
