//! Design-space exploration over SA geometries — what the calibrated
//! implementation models (Tables II/III) enable beyond the paper's
//! three synthesized points: sweep geometry × PDK × variant and report
//! the efficiency frontier.
//!
//! ```sh
//! cargo run --release --example dse
//! ```

use bitsmm::arch::asic::AsicModel;
use bitsmm::arch::fpga::FpgaModel;
use bitsmm::arch::pdk::PdkKind;
use bitsmm::report::{f, Table};
use bitsmm::sim::array::SaConfig;
use bitsmm::sim::mac_common::MacVariant;

fn main() -> bitsmm::Result<()> {
    let geometries: Vec<(usize, usize)> = vec![
        (8, 2),
        (16, 4),
        (16, 8),
        (32, 8),
        (32, 16),
        (64, 16),
        (64, 32),
        (128, 32),
    ];

    // ---- FPGA sweep ---------------------------------------------------
    let fpga = FpgaModel::default();
    let mut t = Table::new(
        "DSE — ZCU104 FPGA @300MHz, 16-bit operands (model extrapolation)",
        &["SA (cols x rows)", "MACs", "LUTs", "Power (W)", "GOPS", "GOPS/W"],
    );
    let zcu104_luts = 230_400u64; // ZU7EV LUT budget
    let mut frontier: Vec<(String, f64, f64)> = Vec::new();
    for &(c, r) in &geometries {
        let imp = fpga.implement(SaConfig::new(r, c, MacVariant::Booth), 16);
        let fits = imp.luts <= zcu104_luts;
        t.row(&[
            format!("{c}x{r}{}", if fits { "" } else { " (exceeds ZU7EV)" }),
            (r * c).to_string(),
            imp.luts.to_string(),
            f(imp.power_w),
            f(imp.gops),
            f(imp.gops_per_w),
        ]);
        if fits {
            frontier.push((format!("{c}x{r}"), imp.gops, imp.gops_per_w));
        }
    }
    print!("{}", t.render());
    let best = frontier
        .iter()
        .max_by(|a, b| a.2.total_cmp(&b.2))
        .expect("nonempty");
    println!("best feasible GOPS/W on ZU7EV: {} at {}\n", f(best.2), best.0);

    // ---- ASIC sweep -----------------------------------------------------
    for kind in [PdkKind::Asap7, PdkKind::Nangate45] {
        let asic = AsicModel::new(kind);
        let mut t = Table::new(
            &format!("DSE — {} (model extrapolation)", kind.name()),
            &["SA", "variant", "fmax (MHz)", "area (mm2)", "GOPS@tgt", "GOPS/mm2", "GOPS/W"],
        );
        for &(c, r) in &geometries {
            for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
                let imp = asic.implement(SaConfig::new(r, c, variant), 16);
                t.row(&[
                    format!("{c}x{r}"),
                    variant.name().into(),
                    f(imp.max_freq_mhz),
                    format!("{:.4}", imp.area_mm2),
                    f(imp.gops_at_target),
                    f(imp.gops_per_mm2),
                    f(imp.gops_per_w),
                ]);
            }
        }
        print!("{}", t.render());
    }

    // ---- aspect-ratio study --------------------------------------------
    // Same MAC budget, different shapes: readout latency (rows·cols) is
    // fixed, but tiling efficiency against a batch-8 MLP differs.
    let mut t = Table::new(
        "DSE — aspect ratio at a 256-MAC budget (batch-8 MLP tiling)",
        &["SA", "tiles for 8x64x64", "modelled cycles", "achieved OP/cycle"],
    );
    for &(c, r) in &[(256usize, 1usize), (64, 4), (32, 8), (16, 16)] {
        let sa = SaConfig::new(r, c, MacVariant::Booth);
        let plan = bitsmm::coordinator::tile_matmul(8, 64, 64, &sa);
        let cycles = plan.total_cycles(&sa, 8);
        t.row(&[
            format!("{c}x{r}"),
            plan.jobs.len().to_string(),
            cycles.to_string(),
            f(plan.total_macs() as f64 / cycles as f64),
        ]);
    }
    print!("{}", t.render());
    println!("dse OK");
    Ok(())
}
